"""Fused project+gram Pallas kernel: one X read → (P = XQ, C = PᵀP).

Final-pass hot spot (Algorithm 1 lines 15-17): the projected covariance
``C = Qᵀ Xᵀ X Q`` is computed as the Gram of ``P = X Q``.  Fusing both
matmuls into one kernel means X is read from HBM exactly once per pass
and P never makes an HBM round-trip before the Gram — the remaining P
write-out is only needed for the cross term F (done as a TN matmul on
the emitted Pa, Pb).

Column-bucketed grid (kt_t, n_t, d_t), C-column buckets outermost and
the contraction (d) innermost:

- the k̃ output columns of C are split into buckets of ``bc`` with
  ``k̃p·bc ≤ VMEM_BLOCK_ELEMS`` (the shared per-buffer budget from
  :mod:`repro.kernels.matmul`);
- per bucket, per row tile, the FULL P tile (bn, k̃p) accumulates in
  VMEM scratch over the d steps; on the last d step the tile is
  written out and ``C[:, bucket] += Pᵀ P[:, bucket]`` lands in the
  (k̃p, bc) block, whose index map is constant in (n_t, d_t) — each
  bucket's block stays VMEM-resident across row steps and hits HBM
  once;
- the P output tile is rewritten (identically) once per bucket so its
  buffer never carries stale data across bucket revisits.

When ``k̃p² ≤ VMEM_BLOCK_ELEMS`` (k̃p ≤ 1024) a single bucket covers C
and the schedule matches the old 2-axis grid exactly.  Larger sketches
(the paper's Europarl run has k̃ = 2060) now stay fused.

TWO SCHEDULES, ONE COST MODEL.  The bucketed *recompute* schedule
above re-reads X and re-accumulates ``P = XQ`` once per C-column
bucket — ``n_buckets·proj + gram`` FLOPs versus the unfused pair's
single projection plus P round-trip.  The bucket count here is only
``k̃p/bc`` (17 for Europarl, not thousands), but for d ≫ k̃ the
projection dominates.  The *staged* schedule (``schedule="staged"``,
requires ``p_dtype=float32``) reuses the powerpass phase-1 kernel
(:func:`repro.kernels.powerpass.proj_stage`): P is projected exactly
once into its f32 output buffer — which the final pass has to emit
anyway for the cross term F — and phase 2 (the ``gram_sweep`` kernel,
grid (kt_t, n_t)) computes ``C[:, bucket] += Pᵀ P[:, bucket]`` reloading
the staged P tiles.  Cost: ``proj + gram`` FLOPs plus ``n_buckets``
re-reads of P, with no extra round-trip at all (the P write-out was
already part of the contract).  Both schedules issue bitwise-identical
f32 dot sequences; :func:`choose_projgram_schedule` picks per shape via
the shared roofline crossover
(:func:`repro.kernels.matmul.pick_schedule`), overridden by autotuned
``op="projgram-staged"`` cache entries.  The unfused matmul-pair
fallback remains only for degenerate ``k̃p > 8192`` where even a
128-column C block (or a 128-row P/Q tile) blows the budget.

Block caps resolve from the autotune cache (``op="projgram"``) — see
:func:`repro.kernels.autotune.autotune_projgram` and
``benchmarks/sweep_blocks.py``.  The staged schedule resolves blocks
through the same lookup, so both schedules tile identically.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import autotune, rand
from .compat import tpu_compiler_params
from .matmul import (_pad2, _pick_block, _round_up, pallas_matmul,
                     pick_schedule, vmem_row_cap)
from .plan import BlockDef, KernelPlan, ScalarDef, ScratchDef, launch_args
from .powerpass import (_proj_stage_kernel, _proj_stage_seeded_kernel,
                        plan_proj_stage, plan_proj_stage_seeded)


def _projgram_kernel(x_ref, q_ref, p_ref, c_ref, acc_ref,
                     *, n_d_steps: int, block_c: int):
    """grid (kt_t, n_t, d_t), d innermost.  acc_ref: (bn, k̃p) P tile."""
    c_step = pl.program_id(0)
    n_step = pl.program_id(1)
    d_step = pl.program_id(2)

    @pl.when(jnp.logical_and(n_step == 0, d_step == 0))
    def _init_c():
        c_ref[...] = jnp.zeros_like(c_ref)

    @pl.when(d_step == 0)
    def _init_p():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], q_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(d_step == n_d_steps - 1)
    def _flush():
        p = acc_ref[...]
        p_ref[...] = p.astype(p_ref.dtype)
        pj = acc_ref[:, pl.ds(c_step * block_c, block_c)]
        c_ref[...] += jax.lax.dot_general(  # Pᵀ P[:, bucket] on the MXU
            p, pj, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        ).astype(c_ref.dtype)


def resolve_blocks(
    np_: int, dp: int, ktp: int,
    block_n: int, block_d: int, block_c: int,
) -> tuple[int, int, int] | None:
    """Effective (bn, bd, bc) for the bucketed grid, or ``None`` when
    the shape is degenerate (k̃p > 8192).  bn·k̃p (P tile/scratch),
    bd·k̃p (Q tile) and k̃p·bc (C bucket) all stay within the shared
    ``VMEM_BLOCK_ELEMS`` budget; a bucket covering all of k̃p is
    preferred when it fits (single-block schedule for k̃p ≤ 1024)."""
    row_cap = vmem_row_cap(ktp)
    if row_cap < 128:
        return None
    cap_c = min(block_c, row_cap)
    bc = ktp if ktp <= cap_c else _pick_block(ktp, cap_c)
    bn = _pick_block(np_, min(block_n, row_cap))
    bd = _pick_block(dp, min(block_d, row_cap))
    return bn, bd, bc


def plan_projgram(n: int, d: int, kt: int, dtype, *,
                  block_n: int | None = None, block_d: int | None = None,
                  block_c: int | None = None,
                  p_dtype=jnp.float32) -> KernelPlan | None:
    """Launch plan for the fused project+gram kernel, or ``None`` for
    the degenerate unfused-fallback shapes (k̃p > 8192).  Block caps
    resolve exactly as in the wrapper (autotune cache, then the shared
    VMEM budget) — the static checker consumes the same plan."""
    np_, dp, ktp = _round_up(n, 128), _round_up(d, 128), _round_up(kt, 128)
    if block_n is None or block_d is None or block_c is None:
        tuned = autotune.lookup("projgram", np_, dp, ktp, dtype)
        block_n = tuned[0] if block_n is None else block_n
        block_d = tuned[1] if block_d is None else block_d
        block_c = tuned[2] if block_c is None else block_c
    blocks = resolve_blocks(np_, dp, ktp, block_n, block_d, block_c)
    if blocks is None:
        return None
    bn, bd, bc = blocks
    in_dt = str(jnp.dtype(dtype))
    return KernelPlan(
        name="projgram",
        grid=(ktp // bc, np_ // bn, dp // bd),
        in_specs=(
            BlockDef((bn, bd), lambda j, i, k: (i, k), (np_, dp), in_dt),
            BlockDef((bd, ktp), lambda j, i, k: (k, 0), (dp, ktp), in_dt),
        ),
        out_specs=(
            BlockDef((bn, ktp), lambda j, i, k: (i, 0), (np_, ktp),
                     str(jnp.dtype(p_dtype))),
            BlockDef((ktp, bc), lambda j, i, k: (0, j), (ktp, ktp),
                     "float32"),
        ),
        scratch=(ScratchDef((bn, ktp), "float32"),),
        out_shape=((n, kt), (kt, kt)),
        accum_outputs=(1,),
    )


@functools.partial(
    jax.jit,
    static_argnames=("block_n", "block_d", "block_c", "schedule", "interpret",
                     "p_dtype"),
)
def projgram(
    x: jax.Array,
    q: jax.Array,
    *,
    block_n: int | None = None,
    block_d: int | None = None,
    block_c: int | None = None,
    schedule: str | None = None,
    p_dtype=jnp.float32,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Return (P = x@q, C = PᵀP) with x read once per C-column bucket.

    x: (n, d), q: (d, k̃).  ``block_c`` caps the C-column bucket;
    ``None`` caps resolve from the autotune cache (``op="projgram"``)
    and then from the shared VMEM budget.

    ``schedule`` picks ``"recompute"`` or ``"staged"`` (P projected
    once, Gram buckets reload it; requires ``p_dtype`` f32); ``None``
    resolves per shape via :func:`choose_projgram_schedule`.  Both
    schedules are bitwise equal.
    """
    n, d = x.shape
    d2, kt = q.shape
    assert d == d2
    plan = plan_projgram(n, d, kt, x.dtype, block_n=block_n, block_d=block_d,
                         block_c=block_c, p_dtype=p_dtype)
    if plan is None:
        # k̃p > 8192: no 128-wide block fits the budget — unfused fallback
        p = pallas_matmul(x, q, out_dtype=p_dtype, interpret=interpret)
        c = pallas_matmul(p, p, transpose_lhs=True, interpret=interpret)
        return p, c
    if schedule is None:
        schedule = choose_projgram_schedule(
            n, d, kt, x.dtype, block_n=block_n, block_d=block_d,
            block_c=block_c, p_dtype=p_dtype)
    if schedule == "staged":
        plans = plan_projgram_staged(n, d, kt, x.dtype, block_n=block_n,
                                     block_d=block_d, block_c=block_c,
                                     p_dtype=p_dtype)
        if plans is not None:
            stage, gram = plans
            xp = _pad2(x, *stage.in_specs[0].padded)
            qp = _pad2(q, *stage.in_specs[1].padded)
            p, c = _staged_gram_call(xp, qp, stage, gram, interpret)
            return p[:n, :kt], c[:kt, :kt]
    xp = _pad2(x, *plan.in_specs[0].padded)
    qp = _pad2(q, *plan.in_specs[1].padded)

    p, c = pl.pallas_call(
        functools.partial(_projgram_kernel, n_d_steps=plan.grid[2],
                          block_c=plan.out_specs[1].shape[1]),
        **launch_args(plan),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
    )(xp, qp)
    return p[:n, :kt], c[:kt, :kt]


def _projgram_seeded_kernel(seed_ref, x_ref, p_ref, c_ref, acc_ref, *,
                            n_d_steps: int, block_c: int, bd: int, ktp: int,
                            d: int, kt: int, q_dtype):
    """Seeded-Ω variant of :func:`_projgram_kernel`: the (bd, k̃p) Q
    tile is regenerated from the SMEM seed at global row offset
    ``d_step·bd`` (f32 → zero-mask outside (d, k̃) → one cast), bitwise
    identical to streaming a zero-padded ``rand.dense_omega`` tile."""
    c_step = pl.program_id(0)
    n_step = pl.program_id(1)
    d_step = pl.program_id(2)

    @pl.when(jnp.logical_and(n_step == 0, d_step == 0))
    def _init_c():
        c_ref[...] = jnp.zeros_like(c_ref)

    @pl.when(d_step == 0)
    def _init_p():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_tile = rand.normal_tile(
        seed_ref[0], seed_ref[1],
        (d_step * bd).astype(rand.U32), rand.U32(0),
        (bd, ktp), row_limit=d, col_limit=kt,
    ).astype(q_dtype)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], q_tile, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(d_step == n_d_steps - 1)
    def _flush():
        p = acc_ref[...]
        p_ref[...] = p.astype(p_ref.dtype)
        pj = acc_ref[:, pl.ds(c_step * block_c, block_c)]
        c_ref[...] += jax.lax.dot_general(
            p, pj, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        ).astype(c_ref.dtype)


def plan_projgram_seeded(n: int, d: int, kt: int, dtype, *,
                         block_n: int | None = None,
                         block_d: int | None = None,
                         block_c: int | None = None,
                         p_dtype=jnp.float32) -> KernelPlan | None:
    """Launch plan for the seeded project+gram kernel: the materialized
    plan's geometry with the Q operand replaced by a (2,)-uint32 SMEM
    seed scalar."""
    base = plan_projgram(n, d, kt, dtype, block_n=block_n, block_d=block_d,
                         block_c=block_c, p_dtype=p_dtype)
    if base is None:
        return None
    return dataclasses.replace(
        base,
        name="projgram_seeded",
        in_specs=base.in_specs[:1],
        scalars=(ScalarDef((2,), "uint32"),),
    )


@functools.partial(
    jax.jit,
    static_argnames=("kt", "q_dtype", "block_n", "block_d", "block_c",
                     "schedule", "interpret", "p_dtype"),
)
def projgram_seeded(
    x: jax.Array,
    seed: jax.Array,
    *,
    kt: int,
    q_dtype=None,
    block_n: int | None = None,
    block_d: int | None = None,
    block_c: int | None = None,
    schedule: str | None = None,
    p_dtype=jnp.float32,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Return (P = x @ Ω(seed), C = PᵀP) with Ω generated in-kernel.

    x: (n, d), seed: (2,) uint32.  Bitwise identical to
    ``projgram(x, rand.dense_omega(seed, d, kt, q_dtype))``; only the
    degenerate unfused fallback (k̃p > 8192) materializes Ω transiently.
    ``schedule`` as in :func:`projgram`; under ``"staged"`` each Ω tile
    is generated exactly once, in phase 1.
    """
    n, d = x.shape
    q_dtype = x.dtype if q_dtype is None else jnp.dtype(q_dtype)
    plan = plan_projgram_seeded(n, d, kt, x.dtype, block_n=block_n,
                                block_d=block_d, block_c=block_c,
                                p_dtype=p_dtype)
    if plan is None:
        q = rand.dense_omega(seed, d, kt, q_dtype)
        p = pallas_matmul(x, q, out_dtype=p_dtype, interpret=interpret)
        c = pallas_matmul(p, p, transpose_lhs=True, interpret=interpret)
        return p, c
    if schedule is None:
        schedule = choose_projgram_schedule(
            n, d, kt, x.dtype, block_n=block_n, block_d=block_d,
            block_c=block_c, p_dtype=p_dtype)
    if schedule == "staged":
        plans = plan_projgram_staged(n, d, kt, x.dtype, block_n=block_n,
                                     block_d=block_d, block_c=block_c,
                                     p_dtype=p_dtype, seeded=True)
        if plans is not None:
            stage, gram = plans
            xp = _pad2(x, *stage.in_specs[0].padded)
            bd = stage.in_specs[0].shape[1]
            ktp = stage.out_specs[0].shape[1]
            p, c = _staged_gram_call(
                xp, jnp.asarray(seed, jnp.uint32), stage, gram, interpret,
                seeded_kwargs=dict(bd=bd, ktp=ktp, d=d, kt=kt,
                                   q_dtype=q_dtype))
            return p[:n, :kt], c[:kt, :kt]
    xp = _pad2(x, *plan.in_specs[0].padded)
    bd = plan.in_specs[0].shape[1]
    ktp = plan.out_specs[0].shape[1]

    p, c = pl.pallas_call(
        functools.partial(_projgram_seeded_kernel, n_d_steps=plan.grid[2],
                          block_c=plan.out_specs[1].shape[1],
                          bd=bd, ktp=ktp, d=d, kt=kt, q_dtype=q_dtype),
        **launch_args(plan),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
    )(jnp.asarray(seed, jnp.uint32), xp)
    return p[:n, :kt], c[:kt, :kt]


# --------------------------------------------------------------------------
# staged (P-reuse) schedule: project once, sweep the Gram buckets
# --------------------------------------------------------------------------


def _gram_sweep_kernel(p_ref, c_ref, *, block_c: int):
    """Phase 2: C[:, bucket] += Pᵀ P[:, bucket]; grid (kt_t, n_t), rows
    innermost.  Reloads the staged (bn, k̃p) P tiles once per C-column
    bucket — the same f32 dot the recompute schedule issues on its last
    d step, so the two schedules are bitwise equal."""
    c_step = pl.program_id(0)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    p = p_ref[...]
    pj = p_ref[:, pl.ds(c_step * block_c, block_c)]
    c_ref[...] += jax.lax.dot_general(  # Pᵀ P[:, bucket] on the MXU
        p, pj, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def plan_gram_sweep(n: int, kt: int, *,
                    bn: int | None = None,
                    bc: int | None = None) -> KernelPlan | None:
    """Launch plan for the phase-2 Gram sweep (C = PᵀP, bucketed).

    ``bn``/``bc`` are resolved blocks when given (the staged composite
    passes the recompute plan's blocks verbatim); ``None`` resolves
    standalone from the shared VMEM budget.
    """
    np_, ktp = _round_up(n, 128), _round_up(kt, 128)
    row_cap = vmem_row_cap(ktp)
    if row_cap < 128:
        return None
    if bc is None:
        bc = ktp if ktp <= row_cap else _pick_block(ktp, row_cap)
    if bn is None:
        bn = _pick_block(np_, min(256, row_cap))
    return KernelPlan(
        name="gram_sweep",
        grid=(ktp // bc, np_ // bn),
        in_specs=(
            BlockDef((bn, ktp), lambda j, i: (i, 0), (np_, ktp), "float32"),
        ),
        out_specs=(
            BlockDef((ktp, bc), lambda j, i: (0, j), (ktp, ktp), "float32"),
        ),
        scratch=(),
        out_shape=((kt, kt),),
        accum_outputs=(0,),
    )


def plan_projgram_staged(
    n: int, d: int, kt: int, dtype, *,
    block_n: int | None = None, block_d: int | None = None,
    block_c: int | None = None, p_dtype=jnp.float32, seeded: bool = False,
) -> tuple[KernelPlan, KernelPlan] | None:
    """(stage, gram_sweep) plan pair for the staged schedule, or
    ``None`` on degenerate shapes or when ``p_dtype`` is not f32 (the
    staged P *is* the emitted P buffer, and parity requires it exact).
    Blocks are extracted from the recompute plan for the same shape, so
    both schedules tile identically."""
    if jnp.dtype(p_dtype) != jnp.float32:
        return None
    base = plan_projgram(n, d, kt, dtype, block_n=block_n, block_d=block_d,
                         block_c=block_c, p_dtype=p_dtype)
    if base is None:
        return None
    bn, bd = base.in_specs[0].shape
    bc = base.out_specs[1].shape[1]
    if seeded:
        stage = plan_proj_stage_seeded(n, d, kt, dtype, bn=bn, bd=bd)
    else:
        stage = plan_proj_stage(n, d, kt, dtype, bn=bn, bd=bd)
    gram = plan_gram_sweep(n, kt, bn=bn, bc=bc)
    if stage is None or gram is None:
        return None
    return stage, gram


def choose_projgram_schedule(
    n: int, d: int, kt: int, dtype, *,
    block_n: int | None = None, block_d: int | None = None,
    block_c: int | None = None, p_dtype=jnp.float32,
) -> str:
    """``"staged"`` or ``"recompute"`` for one projgram shape — same
    order of authority as
    :func:`repro.kernels.powerpass.choose_powerpass_schedule`: autotuned
    ``op="projgram-staged"`` entry, then the analytic roofline crossover
    over the plan-derived cost model.  Non-f32 ``p_dtype`` always
    recomputes (the staged schedule's P buffer must stay exact)."""
    if jnp.dtype(p_dtype) != jnp.float32:
        return "recompute"
    np_, dp, ktp = _round_up(n, 128), _round_up(d, 128), _round_up(kt, 128)
    tuned = autotune.lookup_schedule("projgram-staged", (np_, dp, ktp), dtype)
    if tuned is not None:
        return tuned
    base = plan_projgram(n, d, kt, dtype, block_n=block_n, block_d=block_d,
                         block_c=block_c, p_dtype=p_dtype)
    if base is None or base.grid[0] == 1:
        return "recompute"
    plans = plan_projgram_staged(n, d, kt, dtype, block_n=block_n,
                                 block_d=block_d, block_c=block_c,
                                 p_dtype=p_dtype)
    if plans is None:
        return "recompute"
    from repro.obs.cost import plan_cost  # deferred: obs imports kernels.plan

    rec = plan_cost(base)
    stage, gram = (plan_cost(p) for p in plans)
    return pick_schedule({
        "recompute": (rec["flops"], rec["bytes"]),
        "staged": (stage["flops"] + gram["flops"],
                   stage["bytes"] + gram["bytes"]),
    })


def _staged_gram_call(xp, q_or_seed, stage: KernelPlan, gram: KernelPlan,
                      interpret: bool, *, seeded_kwargs=None):
    """Launch the (stage, gram_sweep) pallas_call pair; returns the
    padded (P, C).  P is the staged f32 buffer itself — the final pass
    emits it anyway for the cross term F, so staging is free here."""
    if seeded_kwargs is None:
        body = _proj_stage_kernel
        operands = (xp, q_or_seed)
    else:
        body = functools.partial(_proj_stage_seeded_kernel, **seeded_kwargs)
        operands = (q_or_seed, xp)  # seed scalar leads the blocked operands
    p = pl.pallas_call(
        body,
        **launch_args(stage),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
    )(*operands)
    c = pl.pallas_call(
        functools.partial(_gram_sweep_kernel,
                          block_c=gram.out_specs[0].shape[1]),
        **launch_args(gram),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
    )(p)
    return p, c


@functools.partial(jax.jit, static_argnames=("interpret",))
def gram_sweep(p: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Standalone phase-2 Gram sweep: C = pᵀ p, reloading P tiles per
    C-column bucket.  p: (n, k̃) f32 (or the compute dtype on the
    sharded collective-fused path) → (k̃, k̃) f32.  Registry entry point
    for the ``gram_sweep`` contract checks."""
    n, kt = p.shape
    plan = plan_gram_sweep(n, kt)
    if plan is None:
        return pallas_matmul(p, p, transpose_lhs=True, interpret=interpret)
    pp = _pad2(p, *plan.in_specs[0].padded)
    if plan.in_specs[0].dtype != str(p.dtype):
        plan = dataclasses.replace(
            plan,
            in_specs=(dataclasses.replace(plan.in_specs[0],
                                          dtype=str(p.dtype)),),
        )
    c = pl.pallas_call(
        functools.partial(_gram_sweep_kernel,
                          block_c=plan.out_specs[0].shape[1]),
        **launch_args(plan),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
    )(pp)
    return c[:kt, :kt]
