"""Fused project+gram Pallas kernel: one X read → (P = XQ, C = PᵀP).

Final-pass hot spot (Algorithm 1 lines 15-17): the projected covariance
``C = Qᵀ Xᵀ X Q`` is computed as the Gram of ``P = X Q``.  Fusing both
matmuls into one kernel means X is read from HBM exactly once per pass
and P never makes an HBM round-trip before the Gram — the remaining P
write-out is only needed for the cross term F (done as a TN matmul on
the emitted Pa, Pb).

Column-bucketed grid (kt_t, n_t, d_t), C-column buckets outermost and
the contraction (d) innermost:

- the k̃ output columns of C are split into buckets of ``bc`` with
  ``k̃p·bc ≤ VMEM_BLOCK_ELEMS`` (the shared per-buffer budget from
  :mod:`repro.kernels.matmul`);
- per bucket, per row tile, the FULL P tile (bn, k̃p) accumulates in
  VMEM scratch over the d steps; on the last d step the tile is
  written out and ``C[:, bucket] += Pᵀ P[:, bucket]`` lands in the
  (k̃p, bc) block, whose index map is constant in (n_t, d_t) — each
  bucket's block stays VMEM-resident across row steps and hits HBM
  once;
- the P output tile is rewritten (identically) once per bucket so its
  buffer never carries stale data across bucket revisits.

When ``k̃p² ≤ VMEM_BLOCK_ELEMS`` (k̃p ≤ 1024) a single bucket covers C
and the schedule matches the old 2-axis grid exactly.  Larger sketches
(the paper's Europarl run has k̃ = 2060) now stay fused.  COST MODEL:
with the bucket axis outermost, X is re-read and ``P = XQ``
re-accumulated once per C-column bucket — ``n_buckets·proj`` FLOPs
versus the unfused pair's single projection plus P round-trip.  The
bucket count here is only ``k̃p/bc`` (17 for Europarl, not thousands),
but for d ≫ k̃ the projection dominates, so sweep the TPU target
(``make sweep-blocks``) before trusting the fused default at large
k̃ — and see ROADMAP for the P-reuse schedule that removes the
recompute.  The unfused matmul-pair fallback remains only for
degenerate ``k̃p > 8192`` where even a 128-column C block (or a
128-row P/Q tile) blows the budget.

Block caps resolve from the autotune cache (``op="projgram"``) — see
:func:`repro.kernels.autotune.autotune_projgram` and
``benchmarks/sweep_blocks.py``.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import autotune, rand
from .compat import tpu_compiler_params
from .matmul import _pad2, _pick_block, _round_up, pallas_matmul, vmem_row_cap
from .plan import BlockDef, KernelPlan, ScalarDef, ScratchDef, launch_args


def _projgram_kernel(x_ref, q_ref, p_ref, c_ref, acc_ref,
                     *, n_d_steps: int, block_c: int):
    """grid (kt_t, n_t, d_t), d innermost.  acc_ref: (bn, k̃p) P tile."""
    c_step = pl.program_id(0)
    n_step = pl.program_id(1)
    d_step = pl.program_id(2)

    @pl.when(jnp.logical_and(n_step == 0, d_step == 0))
    def _init_c():
        c_ref[...] = jnp.zeros_like(c_ref)

    @pl.when(d_step == 0)
    def _init_p():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], q_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(d_step == n_d_steps - 1)
    def _flush():
        p = acc_ref[...]
        p_ref[...] = p.astype(p_ref.dtype)
        pj = acc_ref[:, pl.ds(c_step * block_c, block_c)]
        c_ref[...] += jax.lax.dot_general(  # Pᵀ P[:, bucket] on the MXU
            p, pj, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        ).astype(c_ref.dtype)


def resolve_blocks(
    np_: int, dp: int, ktp: int,
    block_n: int, block_d: int, block_c: int,
) -> tuple[int, int, int] | None:
    """Effective (bn, bd, bc) for the bucketed grid, or ``None`` when
    the shape is degenerate (k̃p > 8192).  bn·k̃p (P tile/scratch),
    bd·k̃p (Q tile) and k̃p·bc (C bucket) all stay within the shared
    ``VMEM_BLOCK_ELEMS`` budget; a bucket covering all of k̃p is
    preferred when it fits (single-block schedule for k̃p ≤ 1024)."""
    row_cap = vmem_row_cap(ktp)
    if row_cap < 128:
        return None
    cap_c = min(block_c, row_cap)
    bc = ktp if ktp <= cap_c else _pick_block(ktp, cap_c)
    bn = _pick_block(np_, min(block_n, row_cap))
    bd = _pick_block(dp, min(block_d, row_cap))
    return bn, bd, bc


def plan_projgram(n: int, d: int, kt: int, dtype, *,
                  block_n: int | None = None, block_d: int | None = None,
                  block_c: int | None = None,
                  p_dtype=jnp.float32) -> KernelPlan | None:
    """Launch plan for the fused project+gram kernel, or ``None`` for
    the degenerate unfused-fallback shapes (k̃p > 8192).  Block caps
    resolve exactly as in the wrapper (autotune cache, then the shared
    VMEM budget) — the static checker consumes the same plan."""
    np_, dp, ktp = _round_up(n, 128), _round_up(d, 128), _round_up(kt, 128)
    if block_n is None or block_d is None or block_c is None:
        tuned = autotune.lookup("projgram", np_, dp, ktp, dtype)
        block_n = tuned[0] if block_n is None else block_n
        block_d = tuned[1] if block_d is None else block_d
        block_c = tuned[2] if block_c is None else block_c
    blocks = resolve_blocks(np_, dp, ktp, block_n, block_d, block_c)
    if blocks is None:
        return None
    bn, bd, bc = blocks
    in_dt = str(jnp.dtype(dtype))
    return KernelPlan(
        name="projgram",
        grid=(ktp // bc, np_ // bn, dp // bd),
        in_specs=(
            BlockDef((bn, bd), lambda j, i, k: (i, k), (np_, dp), in_dt),
            BlockDef((bd, ktp), lambda j, i, k: (k, 0), (dp, ktp), in_dt),
        ),
        out_specs=(
            BlockDef((bn, ktp), lambda j, i, k: (i, 0), (np_, ktp),
                     str(jnp.dtype(p_dtype))),
            BlockDef((ktp, bc), lambda j, i, k: (0, j), (ktp, ktp),
                     "float32"),
        ),
        scratch=(ScratchDef((bn, ktp), "float32"),),
        out_shape=((n, kt), (kt, kt)),
        accum_outputs=(1,),
    )


@functools.partial(
    jax.jit,
    static_argnames=("block_n", "block_d", "block_c", "interpret", "p_dtype"),
)
def projgram(
    x: jax.Array,
    q: jax.Array,
    *,
    block_n: int | None = None,
    block_d: int | None = None,
    block_c: int | None = None,
    p_dtype=jnp.float32,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Return (P = x@q, C = PᵀP) with x read once per C-column bucket.

    x: (n, d), q: (d, k̃).  ``block_c`` caps the C-column bucket;
    ``None`` caps resolve from the autotune cache (``op="projgram"``)
    and then from the shared VMEM budget.
    """
    n, d = x.shape
    d2, kt = q.shape
    assert d == d2
    plan = plan_projgram(n, d, kt, x.dtype, block_n=block_n, block_d=block_d,
                         block_c=block_c, p_dtype=p_dtype)
    if plan is None:
        # k̃p > 8192: no 128-wide block fits the budget — unfused fallback
        p = pallas_matmul(x, q, out_dtype=p_dtype, interpret=interpret)
        c = pallas_matmul(p, p, transpose_lhs=True, interpret=interpret)
        return p, c
    xp = _pad2(x, *plan.in_specs[0].padded)
    qp = _pad2(q, *plan.in_specs[1].padded)

    p, c = pl.pallas_call(
        functools.partial(_projgram_kernel, n_d_steps=plan.grid[2],
                          block_c=plan.out_specs[1].shape[1]),
        **launch_args(plan),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
    )(xp, qp)
    return p[:n, :kt], c[:kt, :kt]


def _projgram_seeded_kernel(seed_ref, x_ref, p_ref, c_ref, acc_ref, *,
                            n_d_steps: int, block_c: int, bd: int, ktp: int,
                            d: int, kt: int, q_dtype):
    """Seeded-Ω variant of :func:`_projgram_kernel`: the (bd, k̃p) Q
    tile is regenerated from the SMEM seed at global row offset
    ``d_step·bd`` (f32 → zero-mask outside (d, k̃) → one cast), bitwise
    identical to streaming a zero-padded ``rand.dense_omega`` tile."""
    c_step = pl.program_id(0)
    n_step = pl.program_id(1)
    d_step = pl.program_id(2)

    @pl.when(jnp.logical_and(n_step == 0, d_step == 0))
    def _init_c():
        c_ref[...] = jnp.zeros_like(c_ref)

    @pl.when(d_step == 0)
    def _init_p():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_tile = rand.normal_tile(
        seed_ref[0], seed_ref[1],
        (d_step * bd).astype(rand.U32), rand.U32(0),
        (bd, ktp), row_limit=d, col_limit=kt,
    ).astype(q_dtype)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], q_tile, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(d_step == n_d_steps - 1)
    def _flush():
        p = acc_ref[...]
        p_ref[...] = p.astype(p_ref.dtype)
        pj = acc_ref[:, pl.ds(c_step * block_c, block_c)]
        c_ref[...] += jax.lax.dot_general(
            p, pj, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        ).astype(c_ref.dtype)


def plan_projgram_seeded(n: int, d: int, kt: int, dtype, *,
                         block_n: int | None = None,
                         block_d: int | None = None,
                         block_c: int | None = None,
                         p_dtype=jnp.float32) -> KernelPlan | None:
    """Launch plan for the seeded project+gram kernel: the materialized
    plan's geometry with the Q operand replaced by a (2,)-uint32 SMEM
    seed scalar."""
    base = plan_projgram(n, d, kt, dtype, block_n=block_n, block_d=block_d,
                         block_c=block_c, p_dtype=p_dtype)
    if base is None:
        return None
    return dataclasses.replace(
        base,
        name="projgram_seeded",
        in_specs=base.in_specs[:1],
        scalars=(ScalarDef((2,), "uint32"),),
    )


@functools.partial(
    jax.jit,
    static_argnames=("kt", "q_dtype", "block_n", "block_d", "block_c",
                     "interpret", "p_dtype"),
)
def projgram_seeded(
    x: jax.Array,
    seed: jax.Array,
    *,
    kt: int,
    q_dtype=None,
    block_n: int | None = None,
    block_d: int | None = None,
    block_c: int | None = None,
    p_dtype=jnp.float32,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Return (P = x @ Ω(seed), C = PᵀP) with Ω generated in-kernel.

    x: (n, d), seed: (2,) uint32.  Bitwise identical to
    ``projgram(x, rand.dense_omega(seed, d, kt, q_dtype))``; only the
    degenerate unfused fallback (k̃p > 8192) materializes Ω transiently.
    """
    n, d = x.shape
    q_dtype = x.dtype if q_dtype is None else jnp.dtype(q_dtype)
    plan = plan_projgram_seeded(n, d, kt, x.dtype, block_n=block_n,
                                block_d=block_d, block_c=block_c,
                                p_dtype=p_dtype)
    if plan is None:
        q = rand.dense_omega(seed, d, kt, q_dtype)
        p = pallas_matmul(x, q, out_dtype=p_dtype, interpret=interpret)
        c = pallas_matmul(p, p, transpose_lhs=True, interpret=interpret)
        return p, c
    xp = _pad2(x, *plan.in_specs[0].padded)
    bd = plan.in_specs[0].shape[1]
    ktp = plan.out_specs[0].shape[1]

    p, c = pl.pallas_call(
        functools.partial(_projgram_seeded_kernel, n_d_steps=plan.grid[2],
                          block_c=plan.out_specs[1].shape[1],
                          bd=bd, ktp=ktp, d=d, kt=kt, q_dtype=q_dtype),
        **launch_args(plan),
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
    )(jnp.asarray(seed, jnp.uint32), xp)
    return p[:n, :kt], c[:kt, :kt]
