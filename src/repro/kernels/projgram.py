"""Fused project+gram Pallas kernel: one X read → (P = XQ, C = PᵀP).

Final-pass hot spot (Algorithm 1 lines 15-17): the projected covariance
``C = Qᵀ Xᵀ X Q`` is computed as the Gram of ``P = X Q``.  Fusing both
matmuls into one kernel means X is read from HBM exactly once per pass
and P never makes an HBM round-trip before the Gram — the remaining P
write-out is only needed for the cross term F (done as a TN matmul on
the emitted Pa, Pb).

VMEM budget per grid step (bn=256, bd=512, k̃p ≤ 1024, f32):
  X block 0.5 MB + Q block 2 MB + P scratch 1 MB + C block ≤ 4 MB ≤ 8 MB.
For k̃p > 1024 the wrapper falls back to the unfused matmul pair.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import tpu_compiler_params
from .matmul import _pad2, _pick_block, _round_up, pallas_matmul


def _projgram_kernel(x_ref, q_ref, p_ref, c_ref, acc_ref, *, n_d_steps: int):
    """grid (n_t, d_t), d innermost.  acc_ref : (bn, k̃p) running P tile."""
    n_step = pl.program_id(0)
    d_step = pl.program_id(1)

    @pl.when(jnp.logical_and(n_step == 0, d_step == 0))
    def _init_c():
        c_ref[...] = jnp.zeros_like(c_ref)

    @pl.when(d_step == 0)
    def _init_p():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], q_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(d_step == n_d_steps - 1)
    def _flush():
        p = acc_ref[...]
        p_ref[...] = p.astype(p_ref.dtype)
        c_ref[...] += jax.lax.dot_general(  # PᵀP on the MXU
            p, p, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        ).astype(c_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_d", "interpret", "p_dtype")
)
def projgram(
    x: jax.Array,
    q: jax.Array,
    *,
    block_n: int = 256,
    block_d: int = 512,
    p_dtype=jnp.float32,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Return (P = x@q, C = PᵀP) with x read once.  x: (n, d), q: (d, k̃)."""
    n, d = x.shape
    d2, kt = q.shape
    assert d == d2
    ktp = _round_up(kt, 128)
    if ktp > 1024:  # C block would blow VMEM — unfused fallback
        p = pallas_matmul(x, q, out_dtype=p_dtype, interpret=interpret)
        c = pallas_matmul(p, p, transpose_lhs=True, interpret=interpret)
        return p, c

    np_, dp = _round_up(n, 128), _round_up(d, 128)
    bn, bd = _pick_block(np_, block_n), _pick_block(dp, block_d)
    gn, gd = np_ // bn, dp // bd
    xp = _pad2(x, np_, dp)
    qp = _pad2(q, dp, ktp)

    p, c = pl.pallas_call(
        functools.partial(_projgram_kernel, n_d_steps=gd),
        grid=(gn, gd),
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, k: (i, k)),
            pl.BlockSpec((bd, ktp), lambda i, k: (k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, ktp), lambda i, k: (i, 0)),
            pl.BlockSpec((ktp, ktp), lambda i, k: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, ktp), p_dtype),
            jax.ShapeDtypeStruct((ktp, ktp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bn, ktp), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
    )(xp, qp)
    return p[:n, :kt], c[:kt, :kt]
