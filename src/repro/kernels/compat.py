"""Single jax-version shim for the data-pass engine.

jax renames a handful of names the kernel and launch layers depend on;
every version-specific spelling is resolved HERE, once, so
``matmul.py``, ``projgram.py``, ``powerpass.py`` and the launch drivers
never touch them directly:

- ``tpu_compiler_params(...)`` — ``pltpu.CompilerParams`` (jax ≥ 0.5)
  vs ``pltpu.TPUCompilerParams`` (jax 0.4.x).
- ``set_mesh(mesh)`` — context manager making ``mesh`` ambient:
  ``jax.set_mesh`` (jax ≥ 0.5) vs the ``with mesh:`` thread-resources
  context (jax 0.4.x).
- ``cost_analysis(compiled)`` — dict (jax ≥ 0.5) vs single-element
  list of dicts (jax 0.4.x).
- ``count_pallas_calls(jaxpr)`` — recursive jaxpr walk over
  ``jax.core`` containers (the fused-vs-fallback regression metric
  used by tests and benchmarks; jaxpr internals move between jax
  versions, so the walk lives here).
- ``vmem(shape, dtype)`` — a VMEM scratch allocation
  (``pltpu.VMEM``); the ``pltpu`` namespace itself is the
  version-sensitive surface, so kernel modules go through this helper.
- ``smem_spec()`` — a ``pl.BlockSpec`` placing a small scalar operand
  (e.g. the Ω PRNG seed) in SMEM (``pltpu.SMEM``), the scalar-operand
  path for the seeded kernels.
- ``shard_map(f, mesh=..., in_specs=..., out_specs=..., check_rep=...)``
  — ``jax.shard_map`` (jax ≥ 0.6, where ``check_rep`` became
  ``check_vma``) vs ``jax.experimental.shard_map.shard_map``.

``repro.analysis`` lint rule RCCA002 enforces the discipline: no
``pltpu.`` / ``jax.experimental.shard_map`` use outside this module.

Both helpers resolve the spelling at call time (not import time) so a
jax upgrade — or a test monkeypatching one spelling — is picked up
without reloading this module.
"""

from __future__ import annotations

import contextlib

import jax
from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(*, dimension_semantics=None, **kwargs):
    """Build Mosaic compiler params under either jax spelling.

    Accepts the keywords shared by both classes (``dimension_semantics``,
    ``vmem_limit_bytes``, ...) and returns an instance suitable for
    ``pl.pallas_call(compiler_params=...)``.
    """
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(dimension_semantics=dimension_semantics, **kwargs)


def cost_analysis(compiled) -> dict:
    """Normalized ``compiled.cost_analysis()`` — always a (possibly
    empty) dict, whichever container this jax returns."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def count_pallas_calls(closed_jaxpr) -> int:
    """Number of ``pallas_call`` eqns anywhere in a closed jaxpr — the
    fusion-regression metric the kernel tests and BENCH reports assert
    on (2 fused calls per power-pass chunk; a fallback to the unfused
    matmul pair doubles it).  It counts kernel launches, not HBM
    traffic — bucketed grids re-read inputs within one call."""
    import jax.core as core

    def walk(jaxpr):
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                n += 1
            for val in eqn.params.values():
                vals = val if isinstance(val, (list, tuple)) else [val]
                for v in vals:
                    if isinstance(v, core.ClosedJaxpr):
                        n += walk(v.jaxpr)
                    elif isinstance(v, core.Jaxpr):
                        n += walk(v)
        return n

    return walk(closed_jaxpr.jaxpr)


def vmem(shape, dtype):
    """A VMEM scratch-buffer allocation for ``pl.pallas_call``
    (``scratch_shapes=[vmem((bm, bn), jnp.float32)]``) — the one place
    the kernels touch the ``pltpu`` namespace for memory spaces."""
    return pltpu.VMEM(tuple(shape), dtype)


def smem_spec():
    """A ``pl.BlockSpec`` that places a small scalar operand (a PRNG
    seed, a size, ...) in SMEM: no block shape, the full array is
    handed to the kernel and read elementwise (``seed_ref[0]``).

    This is the scalar-operand path for PRNG-bearing kernels — the
    seed rides as data (visible to jit, binding metadata and the
    contract checker), never as a Python-level constant baked into the
    trace.  ``pltpu`` memory spaces are version-sensitive spelling, so
    the helper lives here with :func:`vmem`.
    """
    from jax.experimental import pallas as pl

    space = getattr(pltpu, "SMEM", None)
    if space is None:  # pragma: no cover - future jax spelling
        space = pltpu.TPUMemorySpace.SMEM
    return pl.BlockSpec(memory_space=space)


def shard_map(f, *, mesh, in_specs, out_specs, check_rep=False):
    """``shard_map`` under either jax spelling.

    jax ≥ 0.6 promotes it to ``jax.shard_map`` and renames
    ``check_rep`` → ``check_vma``; jax 0.4.x has only
    ``jax.experimental.shard_map.shard_map`` with ``check_rep``.
    Usable directly or as ``functools.partial(shard_map, mesh=...)``
    decoration, exactly like the upstream function.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm  # noqa: PLC0415
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_rep)
    except TypeError:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_rep)


@contextlib.contextmanager
def set_mesh(mesh):
    """Make ``mesh`` the ambient device mesh for the enclosed block."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        with setter(mesh):
            yield
    else:
        with mesh:
            yield
