"""Block-size autotuner for the Pallas data-pass kernels.

``pallas_matmul``'s (block_m, block_n, block_k) caps were hardcoded at
512³; they now resolve per (backend, op, dtype, padded shape) from a
persistent JSON cache, so a one-off sweep on the target hardware sets
the production tile sizes:

    from repro.kernels import autotune
    autotune.autotune_matmul(x, y)     # sweep candidates, persist winner
    pallas_matmul(x, y)                # subsequent calls pick up the caps

The fused bucketed kernels tune the same way (``autotune_powerpass``,
``autotune_projgram`` — swept in bulk by ``benchmarks/sweep_blocks.py``):
their cache entries carry (block_n, block_contraction, bucket) caps
under op="powerpass"/"projgram", and unswept shapes default to
buckets as large as the shared VMEM budget allows (DEFAULT_OP_CAPS).
The staged-vs-recompute schedule choice tunes the same way
(``autotune_powerpass_staged`` / ``autotune_projgram_staged``): entries
under op="powerpass-staged"/"projgram-staged" carry
``{"schedule": "staged"|"recompute"}`` and override the analytic
crossover rule in ``choose_powerpass_schedule`` /
``choose_projgram_schedule``.

Cache location: ``$RCCA_AUTOTUNE_CACHE``, else
``~/.cache/repro/pallas_autotune.json``.  A missing or corrupt cache —
or an unswept shape — falls back to the :data:`DEFAULT_CAPS` heuristic,
so autotuning is always optional and never required for correctness.

NOTE on ordering: block caps are resolved at TRACE time, and the jitted
wrappers cache compiled executables per shape — a shape already run in
this process keeps its compiled blocks.  Sweep before first use of a
shape (or restart the process) for new entries to take effect.
"""

from __future__ import annotations

import itertools
import json
import os
import time

import jax
import jax.numpy as jnp

# caps applied to (block_m, block_n, block_k) when no tuned entry exists
DEFAULT_CAPS = (512, 512, 512)
_CANDIDATE_CAPS = (128, 256, 512, 1024)

# Fused bucketed kernels: caps are (block_n, block_contraction,
# output-column bucket).  The bucket default is intentionally huge so
# the shared VMEM budget (matmul.VMEM_BLOCK_ELEMS), not the cache,
# sizes unswept buckets — i.e. buckets default to as-large-as-fits.
DEFAULT_OP_CAPS = {
    "powerpass": (256, 512, 1 << 20),
    "projgram": (256, 512, 1 << 20),
}
_BUCKET_CANDIDATE_CAPS = (128, 256, 512, 1024, 2048, 4096, 8192)

_cache: dict | None = None
_cache_file: str | None = None


def cache_path() -> str:
    return os.environ.get(
        "RCCA_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro", "pallas_autotune.json"),
    )


def _load() -> dict:
    global _cache, _cache_file
    path = cache_path()
    if _cache is None or _cache_file != path:
        try:
            with open(path) as f:
                _cache = json.load(f)
        except (OSError, ValueError):
            _cache = {}
        _cache_file = path
    return _cache


def _persist() -> None:
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(_cache, f, indent=2, sort_keys=True)
    except OSError:
        pass  # read-only FS — keep the in-memory entry only


def reset() -> None:
    """Drop the in-memory cache (forces a re-read of the cache file)."""
    global _cache, _cache_file
    _cache = None
    _cache_file = None


def shape_key(op: str, M: int, K: int, N: int, dtype, backend: str | None = None,
              extra: int | None = None) -> str:
    """``extra`` carries a fourth problem dim for ops whose blocks depend
    on it (powerpass: the bucketed dap is not among M/K/N)."""
    backend = backend or jax.default_backend()
    key = f"{backend}|{op}|{jnp.dtype(dtype).name}|{M}x{K}x{N}"
    if extra is not None:
        key += f"x{extra}"
    return key


def lookup(op: str, M: int, K: int, N: int, dtype,
           extra: int | None = None) -> tuple[int, int, int]:
    """Tuned block caps for a padded problem, else the op's defaults
    (DEFAULT_OP_CAPS for the fused bucketed kernels, DEFAULT_CAPS for
    the matmuls).  Malformed entries (hand-edited / stale-format
    caches) also fall back — a bad cache must never break the engine."""
    ent = _load().get(shape_key(op, M, K, N, dtype, extra=extra))
    try:
        bm, bn, bk = (int(b) for b in ent["blocks"])
        return bm, bn, bk
    except (TypeError, KeyError, ValueError):
        return DEFAULT_OP_CAPS.get(op, DEFAULT_CAPS)


def record(op, M, K, N, dtype, blocks, us: float | None = None,
           backend: str | None = None, extra: int | None = None) -> None:
    entry = {"blocks": [int(b) for b in blocks]}
    if us is not None:
        entry["us"] = round(float(us), 1)
    _load()[shape_key(op, M, K, N, dtype, backend, extra=extra)] = entry
    _persist()


def _schedule_key(op: str, dims: tuple, dtype, backend: str | None = None) -> str:
    """Schedule entries reuse the shape-key format: 3 dims map to
    ``MxKxN``, 4 dims add the ``extra`` suffix (powerpass-staged keys
    carry the bucketed dap as the fourth dim)."""
    extra = dims[3] if len(dims) > 3 else None
    return shape_key(op, dims[0], dims[1], dims[2], dtype, backend,
                     extra=extra)


def lookup_schedule(op: str, dims: tuple, dtype) -> str | None:
    """Tuned schedule choice (``"staged"`` / ``"recompute"``) for a
    padded problem under ``op="powerpass-staged"`` / ``"projgram-staged"``,
    or ``None`` when unswept — the caller then applies the analytic
    crossover rule.  Malformed entries read as unswept."""
    ent = _load().get(_schedule_key(op, dims, dtype))
    sched = ent.get("schedule") if isinstance(ent, dict) else None
    return sched if sched in ("staged", "recompute") else None


def record_schedule(op: str, dims: tuple, dtype, schedule: str,
                    us: float | None = None,
                    backend: str | None = None) -> None:
    entry: dict = {"schedule": str(schedule)}
    if us is not None:
        entry["us"] = round(float(us), 1)
    _load()[_schedule_key(op, dims, dtype, backend)] = entry
    _persist()


def candidates(Mp: int, Kp: int, Np: int) -> list[tuple[int, int, int]]:
    """Distinct effective (bm, bn, bk) triples for a padded problem —
    cap combinations that resolve to the same dividing blocks are
    swept once."""
    from .matmul import _pick_block

    seen, out = set(), []
    for cm, cn, ck in itertools.product(_CANDIDATE_CAPS, repeat=3):
        eff = (_pick_block(Mp, cm), _pick_block(Np, cn), _pick_block(Kp, ck))
        if eff not in seen:
            seen.add(eff)
            out.append(eff)
    return out


def autotune_matmul(x: jax.Array, y: jax.Array, *, transpose_lhs: bool = False,
                    interpret: bool | None = None, iters: int = 2,
                    op: str | None = None) -> tuple[int, int, int]:
    """Sweep block caps for one matmul shape; persist and return the winner.

    Candidates that fail to compile (e.g. exceed VMEM) are skipped; if
    every candidate fails, DEFAULT_CAPS is returned and nothing is
    recorded.
    """
    from .matmul import _round_up, pallas_matmul
    from .ops import _default_interpret

    interpret = _default_interpret() if interpret is None else interpret
    if transpose_lhs:
        K, M = x.shape
    else:
        M, K = x.shape
    N = y.shape[1]
    Mp, Kp, Np = _round_up(M, 128), _round_up(K, 128), _round_up(N, 128)
    op = op or ("matmul_tn" if transpose_lhs else "matmul_nn")

    best, best_us = None, float("inf")
    for bm, bn, bk in candidates(Mp, Kp, Np):
        def run():
            return pallas_matmul(x, y, transpose_lhs=transpose_lhs,
                                 block_m=bm, block_n=bn, block_k=bk,
                                 interpret=interpret)
        try:
            jax.block_until_ready(run())  # compile + warm up
            t0 = time.perf_counter()
            out = None
            for _ in range(iters):
                out = run()
            jax.block_until_ready(out)
        except Exception:
            continue
        us = (time.perf_counter() - t0) / iters * 1e6
        if us < best_us:
            best, best_us = (bm, bn, bk), us
    if best is None:
        return DEFAULT_CAPS
    record(op, Mp, Kp, Np, x.dtype, best, us=best_us)
    return best


def _time_candidates(cands: dict, run, iters: int):
    """Time each effective-block candidate; (best_blocks, best_us) or
    (None, inf) when every candidate fails to compile/fit."""
    best, best_us = None, float("inf")
    for eff in cands:
        try:
            jax.block_until_ready(run(eff))  # compile + warm up
            t0 = time.perf_counter()
            out = None
            for _ in range(iters):
                out = run(eff)
            jax.block_until_ready(out)
        except Exception:
            continue
        us = (time.perf_counter() - t0) / iters * 1e6
        if us < best_us:
            best, best_us = eff, us
    return best, best_us


def autotune_powerpass(a: jax.Array, b: jax.Array, q: jax.Array, *,
                       interpret: bool | None = None,
                       iters: int = 2) -> tuple[int, int, int]:
    """Sweep (block_n, block_db, block_da-bucket) for one fused
    project+accumulate shape; persist the winner under op="powerpass".

    Candidate caps resolving to the same effective blocks (via
    ``powerpass.resolve_blocks``) are swept once; a degenerate shape
    (no fused path) returns the op defaults and records nothing.
    """
    from .matmul import _round_up
    from .ops import _default_interpret
    from .powerpass import power_project_accumulate, resolve_blocks

    interpret = _default_interpret() if interpret is None else interpret
    n, da = a.shape
    db, kt = q.shape
    np_, dap = _round_up(n, 128), _round_up(da, 128)
    dbp, ktp = _round_up(db, 128), _round_up(kt, 128)

    cands = {}
    for cn, cdb, cda in itertools.product(
            _CANDIDATE_CAPS, _CANDIDATE_CAPS, _BUCKET_CANDIDATE_CAPS):
        eff = resolve_blocks(np_, dap, dbp, ktp, cn, cdb, cda)
        if eff is not None:
            cands[eff] = None
    if not cands:
        return DEFAULT_OP_CAPS["powerpass"]

    def run(eff):
        bn, bdb, bda = eff
        return power_project_accumulate(
            a, b, q, block_n=bn, block_db=bdb, block_da=bda,
            interpret=interpret)

    best, best_us = _time_candidates(cands, run, iters)
    if best is None:
        return DEFAULT_OP_CAPS["powerpass"]
    record("powerpass", np_, dbp, ktp, a.dtype, best, us=best_us, extra=dap)
    return best


def autotune_projgram(x: jax.Array, q: jax.Array, *,
                      interpret: bool | None = None,
                      iters: int = 2) -> tuple[int, int, int]:
    """Sweep (block_n, block_d, block_c-bucket) for one fused
    project+gram shape; persist the winner under op="projgram"."""
    from .matmul import _round_up
    from .ops import _default_interpret
    from .projgram import projgram, resolve_blocks

    interpret = _default_interpret() if interpret is None else interpret
    n, d = x.shape
    kt = q.shape[1]
    np_, dp, ktp = _round_up(n, 128), _round_up(d, 128), _round_up(kt, 128)

    cands = {}
    for cn, cd, cc in itertools.product(
            _CANDIDATE_CAPS, _CANDIDATE_CAPS, _BUCKET_CANDIDATE_CAPS):
        eff = resolve_blocks(np_, dp, ktp, cn, cd, cc)
        if eff is not None:
            cands[eff] = None
    if not cands:
        return DEFAULT_OP_CAPS["projgram"]

    def run(eff):
        bn, bd, bc = eff
        return projgram(x, q, block_n=bn, block_d=bd, block_c=bc,
                        interpret=interpret)

    best, best_us = _time_candidates(cands, run, iters)
    if best is None:
        return DEFAULT_OP_CAPS["projgram"]
    record("projgram", np_, dp, ktp, x.dtype, best, us=best_us)
    return best


def _time_schedules(run, schedules, iters: int) -> tuple[str | None, float]:
    best, best_us = None, float("inf")
    for sched in schedules:
        try:
            jax.block_until_ready(run(sched))  # compile + warm up
            t0 = time.perf_counter()
            out = None
            for _ in range(iters):
                out = run(sched)
            jax.block_until_ready(out)
        except Exception:
            continue
        us = (time.perf_counter() - t0) / iters * 1e6
        if us < best_us:
            best, best_us = sched, us
    return best, best_us


def autotune_powerpass_staged(a: jax.Array, b: jax.Array, q: jax.Array, *,
                              interpret: bool | None = None,
                              iters: int = 2) -> str:
    """Time the staged vs. recompute powerpass schedules for one shape;
    persist the winner under op="powerpass-staged" so
    ``choose_powerpass_schedule`` prefers the measurement over the
    analytic crossover.  Degenerate shapes return "recompute" and
    record nothing."""
    from .matmul import _round_up
    from .ops import _default_interpret
    from .powerpass import plan_powerpass_staged, power_project_accumulate

    interpret = _default_interpret() if interpret is None else interpret
    n, da = a.shape
    db, kt = q.shape
    np_, dap = _round_up(n, 128), _round_up(da, 128)
    dbp, ktp = _round_up(db, 128), _round_up(kt, 128)
    if plan_powerpass_staged(n, da, db, kt, a.dtype) is None:
        return "recompute"

    def run(sched):
        return power_project_accumulate(a, b, q, schedule=sched,
                                        interpret=interpret)

    best, best_us = _time_schedules(run, ("recompute", "staged"), iters)
    if best is None:
        return "recompute"
    record_schedule("powerpass-staged", (np_, dbp, ktp, dap), a.dtype, best,
                    us=best_us)
    return best


def autotune_projgram_staged(x: jax.Array, q: jax.Array, *,
                             interpret: bool | None = None,
                             iters: int = 2) -> str:
    """Time the staged vs. recompute projgram schedules for one shape;
    persist the winner under op="projgram-staged"."""
    from .matmul import _round_up
    from .ops import _default_interpret
    from .projgram import plan_projgram_staged, projgram

    interpret = _default_interpret() if interpret is None else interpret
    n, d = x.shape
    kt = q.shape[1]
    np_, dp, ktp = _round_up(n, 128), _round_up(d, 128), _round_up(kt, 128)
    if plan_projgram_staged(n, d, kt, x.dtype) is None:
        return "recompute"

    def run(sched):
        return projgram(x, q, schedule=sched, interpret=interpret)

    best, best_us = _time_schedules(run, ("recompute", "staged"), iters)
    if best is None:
        return "recompute"
    record_schedule("projgram-staged", (np_, dp, ktp), x.dtype, best,
                    us=best_us)
    return best
