"""qwen2-vl-2b: 28L dense GQA with M-RoPE.  [arXiv:2409.12191; hf]

[vlm] backbone only — the ViT frontend is a stub; input_specs provides
precomputed patch embeddings merged ahead of the text tokens.
"""

from repro.models import AttnConfig, FFNConfig, ModelConfig

N_PATCHES = 256  # stub: 16×16 patch grid per image


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        d_model=1536,
        n_layers=28,
        vocab=151_936,
        attn=AttnConfig(n_heads=12, n_kv=2, head_dim=128, rope_theta=1_000_000.0, mrope=True),
        ffn=FFNConfig(d_ff=8960, act="silu", gated=True),
        frontend="vision_patches",
        tie_embeddings=True,
        max_seq=32_768,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b-smoke",
        d_model=64,
        n_layers=3,
        vocab=512,
        attn=AttnConfig(n_heads=4, n_kv=2, head_dim=16, rope_theta=1_000_000.0, mrope=True),
        ffn=FFNConfig(d_ff=128, act="silu", gated=True),
        frontend="vision_patches",
        tie_embeddings=True,
        max_seq=256,
    )
