"""deepseek-v2-236b: 60L MLA + MoE (2 shared + 160 routed, top-6).
[arXiv:2405.04434; hf]

MLA: kv_lora=512, q_lora=1536, qk_nope=128, qk_rope=64, v=128.
"""

from repro.models import AttnConfig, FFNConfig, MLAConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    n_layers = 60
    return ModelConfig(
        name="deepseek-v2-236b",
        d_model=5120,
        n_layers=n_layers,
        vocab=102_400,
        attn=AttnConfig(
            n_heads=128, n_kv=128, head_dim=128, rope_theta=10_000.0,
            mla=MLAConfig(q_lora=1536, kv_lora=512, nope_dim=128, rope_dim=64, v_dim=128),
        ),
        ffn=FFNConfig(d_ff=12_288, act="silu", gated=True),  # dense first layer
        moe=MoEConfig(
            n_experts=160, top_k=6, d_ff_expert=1536, dispatch_groups=512,
            n_shared=2, d_ff_shared=3072, n_dense_layers=1,
        ),
        layer_pattern=("attn",) + ("attn_moe",) * (n_layers - 1),
        tie_embeddings=False,
        max_seq=131_072,
    )


def smoke_config() -> ModelConfig:
    n_layers = 3
    return ModelConfig(
        name="deepseek-v2-smoke",
        d_model=64,
        n_layers=n_layers,
        vocab=512,
        attn=AttnConfig(
            n_heads=4, n_kv=4, head_dim=16, rope_theta=10_000.0,
            mla=MLAConfig(q_lora=32, kv_lora=16, nope_dim=16, rope_dim=8, v_dim=16),
        ),
        ffn=FFNConfig(d_ff=128, act="silu", gated=True),
        moe=MoEConfig(
            n_experts=8, top_k=2, d_ff_expert=32,
            n_shared=2, d_ff_shared=64, n_dense_layers=1, capacity_factor=4.0,
        ),
        layer_pattern=("attn",) + ("attn_moe",) * (n_layers - 1),
        tie_embeddings=False,
        max_seq=256,
    )
