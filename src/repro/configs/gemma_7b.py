"""gemma-7b: 28L dense, MHA (kv=16), GeGLU, head_dim=256.
[arXiv:2403.08295; hf]
"""

from repro.models import AttnConfig, FFNConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b",
        d_model=3072,
        n_layers=28,
        vocab=256_000,
        attn=AttnConfig(n_heads=16, n_kv=16, head_dim=256, rope_theta=10_000.0),
        ffn=FFNConfig(d_ff=24_576, act="gelu", gated=True),
        tie_embeddings=True,
        embed_scale=True,
        max_seq=8192,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b-smoke",
        d_model=64,
        n_layers=3,
        vocab=512,
        attn=AttnConfig(n_heads=2, n_kv=2, head_dim=32, rope_theta=10_000.0),
        ffn=FFNConfig(d_ff=192, act="gelu", gated=True),
        tie_embeddings=True,
        embed_scale=True,
        max_seq=256,
    )
