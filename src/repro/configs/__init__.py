"""Architecture registry: one module per assigned architecture.

Each module exports ``config()`` (the exact published configuration)
and ``smoke_config()`` (a reduced same-family variant for CPU tests).
"""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "gemma3_1b",
    "starcoder2_7b",
    "gemma_7b",
    "granite_3_2b",
    "whisper_small",
    "kimi_k2_1t_a32b",
    "deepseek_v2_236b",
    "xlstm_350m",
    "zamba2_7b",
    "qwen2_vl_2b",
    # the paper's own workload as an 11th selectable config
    "europarl_cca",
]

# canonical CLI ids (dashes) → module names
CANONICAL = {a.replace("_", "-"): a for a in ARCH_IDS}


def get_module(arch: str):
    mod = CANONICAL.get(arch, arch).replace("-", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch: str, smoke: bool = False):
    m = get_module(arch)
    return m.smoke_config() if smoke else m.config()


def model_archs() -> list[str]:
    """The 10 LM-family archs (europarl_cca is a CCA workload, not an LM)."""
    return [a.replace("_", "-") for a in ARCH_IDS if a != "europarl_cca"]
