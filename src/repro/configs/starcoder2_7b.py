"""starcoder2-7b: 32L dense GQA code LM.  [arXiv:2402.19173; hf]

GQA kv=4, RoPE; plain-GELU (ungated) MLP per the StarCoder2 paper.
"""

from repro.models import AttnConfig, FFNConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        d_model=4608,
        n_layers=32,
        vocab=49_152,
        attn=AttnConfig(n_heads=36, n_kv=4, head_dim=128, rope_theta=100_000.0),
        ffn=FFNConfig(d_ff=18_432, act="gelu", gated=False),
        tie_embeddings=False,
        max_seq=16_384,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b-smoke",
        d_model=64,
        n_layers=3,
        vocab=512,
        attn=AttnConfig(n_heads=4, n_kv=2, head_dim=16, rope_theta=100_000.0),
        ffn=FFNConfig(d_ff=128, act="gelu", gated=False),
        tie_embeddings=False,
        max_seq=256,
    )
