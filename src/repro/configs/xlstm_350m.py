"""xlstm-350m: 24L alternating mLSTM/sLSTM.  [arXiv:2405.04517; unverified]

Recurrent — O(1) decode state → runs the long_500k cell.
"""

from repro.models import ModelConfig, XLSTMConfig, repeat_pattern


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m",
        d_model=1024,
        n_layers=24,
        vocab=50_304,
        xlstm=XLSTMConfig(n_heads=4, proj_factor_m=2.0, proj_factor_s=1.3333, conv_width=4),
        layer_pattern=repeat_pattern(("mlstm", "slstm"), 24),
        tie_embeddings=True,
        max_seq=1_048_576,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m-smoke",
        d_model=64,
        n_layers=4,
        vocab=512,
        xlstm=XLSTMConfig(n_heads=2, proj_factor_m=2.0, proj_factor_s=1.3333, conv_width=4),
        layer_pattern=repeat_pattern(("mlstm", "slstm"), 4),
        tie_embeddings=True,
        max_seq=256,
    )
