"""whisper-small: 12L enc + 12L dec, d=768.  [arXiv:2212.04356; unverified]

[audio] backbone only — the conv/mel frontend is a stub; input_specs
provides precomputed frame embeddings (B, n_frames, d_model).
"""

from repro.models import AttnConfig, FFNConfig, ModelConfig

N_FRAMES = 1500  # 30 s of audio at 50 Hz after conv stride — stub length


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        d_model=768,
        n_layers=12,
        n_enc_layers=12,
        vocab=51_865,
        attn=AttnConfig(n_heads=12, n_kv=12, head_dim=64, rope_theta=0.0),
        ffn=FFNConfig(d_ff=3072, act="gelu", gated=False),
        kind="encdec",
        frontend="audio_frames",
        tie_embeddings=True,
        max_seq=32_768 + 8,  # decoder learned positions (assigned shapes go to 32k)
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small-smoke",
        d_model=64,
        n_layers=2,
        n_enc_layers=2,
        vocab=512,
        attn=AttnConfig(n_heads=4, n_kv=4, head_dim=16, rope_theta=0.0),
        ffn=FFNConfig(d_ff=128, act="gelu", gated=False),
        kind="encdec",
        frontend="audio_frames",
        tie_embeddings=True,
        max_seq=128,
    )
