"""The paper's own workload: Europarl-scale RandomizedCCA.

n = 1,235,976 paired sentences; feature hashing into 2^19 slots per
view; k = 60, p ∈ {910, 2000}, q ∈ {0..3}, ν = 0.01 (paper §4).
"""

import dataclasses

from repro.core.rcca import RCCAConfig


@dataclasses.dataclass(frozen=True)
class CCAWorkload:
    name: str
    n: int
    da: int
    db: int
    rcca: RCCAConfig
    chunk: int  # streaming row-chunk size per data pass


def config() -> CCAWorkload:
    return CCAWorkload(
        name="europarl-cca",
        n=1_235_976,
        da=2**19,
        db=2**19,
        rcca=RCCAConfig(k=60, p=2000, q=1, nu=0.01, center=False),
        chunk=8192,
    )


def smoke_config() -> CCAWorkload:
    return CCAWorkload(
        name="europarl-cca-smoke",
        n=4096,
        da=256,
        db=192,
        rcca=RCCAConfig(k=8, p=24, q=1, nu=0.01, center=False),
        chunk=512,
    )
