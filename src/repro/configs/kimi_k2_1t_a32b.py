"""kimi-k2-1t-a32b: trillion-param MoE, 61L, 384 experts top-8.
[arXiv:2501.kimi2; unverified — paper-table config]

Per the assignment table: GQA kv=8, d_ff(expert)=2048.  First layer
dense (d_ff = 8 experts worth), 1 shared expert.
"""

from repro.models import AttnConfig, FFNConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    n_layers = 61
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        d_model=7168,
        n_layers=n_layers,
        vocab=163_840,
        attn=AttnConfig(n_heads=64, n_kv=8, head_dim=112, rope_theta=50_000.0),
        ffn=FFNConfig(d_ff=16_384, act="silu", gated=True),  # dense first layer
        moe=MoEConfig(
            n_experts=384, top_k=8, d_ff_expert=2048, dispatch_groups=512,
            n_shared=1, d_ff_shared=2048, n_dense_layers=1,
        ),
        layer_pattern=("attn",) + ("attn_moe",) * (n_layers - 1),
        tie_embeddings=False,
        max_seq=131_072,
    )


def smoke_config() -> ModelConfig:
    n_layers = 3
    return ModelConfig(
        name="kimi-k2-smoke",
        d_model=64,
        n_layers=n_layers,
        vocab=512,
        attn=AttnConfig(n_heads=4, n_kv=2, head_dim=16, rope_theta=50_000.0),
        ffn=FFNConfig(d_ff=128, act="silu", gated=True),
        moe=MoEConfig(
            n_experts=8, top_k=2, d_ff_expert=32,
            n_shared=1, d_ff_shared=32, n_dense_layers=1, capacity_factor=4.0,
        ),
        layer_pattern=("attn",) + ("attn_moe",) * (n_layers - 1),
        tie_embeddings=False,
        max_seq=256,
    )
