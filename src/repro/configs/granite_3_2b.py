"""granite-3-2b: 40L dense GQA.  [hf:ibm-granite/granite-3.0-2b-base; hf]"""

from repro.models import AttnConfig, FFNConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b",
        d_model=2048,
        n_layers=40,
        vocab=49_155,
        attn=AttnConfig(n_heads=32, n_kv=8, head_dim=64, rope_theta=10_000.0),
        ffn=FFNConfig(d_ff=8192, act="silu", gated=True),
        tie_embeddings=True,
        max_seq=4096,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b-smoke",
        d_model=64,
        n_layers=4,
        vocab=515,  # deliberately non-round, like the real 49155
        attn=AttnConfig(n_heads=4, n_kv=2, head_dim=16, rope_theta=10_000.0),
        ffn=FFNConfig(d_ff=128, act="silu", gated=True),
        tie_embeddings=True,
        max_seq=256,
    )
