"""zamba2-7b: 81L Mamba2 + one SHARED attention block every 6th layer.
[arXiv:2411.15242; unverified]

Hybrid — recurrent Mamba2 state + a periodically-invoked shared
transformer block (its params are reused at every invocation).
"""

from repro.models import AttnConfig, FFNConfig, ModelConfig, SSMConfig, repeat_pattern


def _pattern(n):
    return repeat_pattern(("shared_attn", "mamba", "mamba", "mamba", "mamba", "mamba"), n)


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        d_model=3584,
        n_layers=81,
        vocab=32_000,
        attn=AttnConfig(n_heads=32, n_kv=32, head_dim=112, rope_theta=10_000.0),
        ffn=FFNConfig(d_ff=14_336, act="silu", gated=True),
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
        layer_pattern=_pattern(81),
        tie_embeddings=True,
        max_seq=1_048_576,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b-smoke",
        d_model=64,
        n_layers=13,  # 2 groups of 6 + 1 tail mamba
        vocab=512,
        attn=AttnConfig(n_heads=4, n_kv=4, head_dim=16, rope_theta=10_000.0),
        ffn=FFNConfig(d_ff=128, act="silu", gated=True),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16),
        layer_pattern=_pattern(13),
        tie_embeddings=True,
        max_seq=256,
    )
