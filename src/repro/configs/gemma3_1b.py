"""gemma3-1b: 26L dense, 5:1 local:global sliding-window attention.

[hf:google/gemma-3-1b-pt; unverified]  GQA kv=1, head_dim=256, GeGLU,
qk-norm, dual rope theta (10k local / 1M global), 128k context.
"""

from repro.models import AttnConfig, FFNConfig, ModelConfig, repeat_pattern


def _pattern(n):
    return repeat_pattern(("local", "local", "local", "local", "local", "attn"), n)


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b",
        d_model=1152,
        n_layers=26,
        vocab=262_144,
        attn=AttnConfig(
            n_heads=4, n_kv=1, head_dim=256,
            rope_theta=1_000_000.0, local_rope_theta=10_000.0,
            window=512, qk_norm=True,
        ),
        ffn=FFNConfig(d_ff=6912, act="gelu", gated=True),
        layer_pattern=_pattern(26),
        tie_embeddings=True,
        embed_scale=True,
        max_seq=131_072,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b-smoke",
        d_model=64,
        n_layers=6,
        vocab=512,
        attn=AttnConfig(
            n_heads=2, n_kv=1, head_dim=32,
            rope_theta=1_000_000.0, local_rope_theta=10_000.0,
            window=16, qk_norm=True,
        ),
        ffn=FFNConfig(d_ff=128, act="gelu", gated=True),
        layer_pattern=_pattern(6),
        tie_embeddings=True,
        embed_scale=True,
        max_seq=256,
    )
