PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify verify-quick verify-cluster verify-topology verify-serve analyze bench bench-kernels bench-io bench-cluster sweep-blocks trajectory

# full tier-1 suite + the interpret-mode kernel-parity subset
verify:
	bash scripts/verify.sh

# only the kernel-parity subset (fast pre-commit check)
verify-quick:
	bash scripts/verify.sh --quick

# only the multi-worker cluster + store suites
verify-cluster:
	bash scripts/verify.sh --cluster

# execution-topology parity (Local ≡ Sharded ≡ Cluster ≡ Hybrid bitwise)
# + hybrid fault tolerance, under a forced 4-device host mesh
verify-topology:
	bash scripts/verify.sh --topology

# serving tier + incremental refits: registry round-trip, zero-drop
# hot-swap, drift → refit signal, delta-refit bitwise parity
verify-serve:
	bash scripts/verify.sh --serve

# static analysis gate: architecture lint + kernel contract checker +
# cluster-protocol model check (+ ruff/mypy when installed)
analyze:
	bash scripts/verify.sh --analyze

# all BENCH jsons + results/TRAJECTORY.json (the committed per-PR perf
# trajectory) through the one stamped entry point (benchmarks.run)
bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --artifacts

# refold results/BENCH_*.json into results/TRAJECTORY.json
trajectory:
	PYTHONPATH=$(PYTHONPATH) python -m repro.obs trajectory

# engine-comparison BENCH json (results/kernel_bench.json)
bench-kernels:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.kernel_bench

# out-of-core IO-overlap BENCH json: store-backed data pass, prefetch
# on vs off (results/BENCH_io.json)
bench-io:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.io_bench --out results/BENCH_io.json

# multi-worker coordinator scaling: rows/s vs workers {1,2,4} + merge
# overhead (results/BENCH_cluster.json)
bench-cluster:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.cluster_bench --out results/BENCH_cluster.json

# autotune sweep for the fused bucketed kernels (powerpass/projgram
# block+bucket caps) plus the staged-vs-recompute schedule timings
# (op="powerpass-staged"/"projgram-staged" cache entries) +
# results/BENCH_bucketed.json
sweep-blocks:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.sweep_blocks --out results/BENCH_bucketed.json
