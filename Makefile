PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify verify-quick bench bench-kernels bench-io sweep-blocks

# full tier-1 suite + the interpret-mode kernel-parity subset
verify:
	bash scripts/verify.sh

# only the kernel-parity subset (fast pre-commit check)
verify-quick:
	bash scripts/verify.sh --quick

# all BENCH jsons (the committed per-PR perf trajectory under results/)
bench: bench-kernels bench-io

# engine-comparison BENCH json (results/kernel_bench.json)
bench-kernels:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.kernel_bench

# out-of-core IO-overlap BENCH json: store-backed data pass, prefetch
# on vs off (results/BENCH_io.json)
bench-io:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.io_bench --out results/BENCH_io.json

# autotune sweep for the fused bucketed kernels (powerpass/projgram
# block+bucket caps) + results/BENCH_bucketed.json
sweep-blocks:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.sweep_blocks --out results/BENCH_bucketed.json
