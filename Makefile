PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify verify-quick bench-kernels sweep-blocks

# full tier-1 suite + the interpret-mode kernel-parity subset
verify:
	bash scripts/verify.sh

# only the kernel-parity subset (fast pre-commit check)
verify-quick:
	bash scripts/verify.sh --quick

# engine-comparison BENCH json (results/kernel_bench.json)
bench-kernels:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.kernel_bench

# autotune sweep for the fused bucketed kernels (powerpass/projgram
# block+bucket caps) + results/BENCH_bucketed.json
sweep-blocks:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.sweep_blocks --out results/BENCH_bucketed.json
