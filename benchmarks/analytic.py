"""Analytic per-device cost model for the roofline (§Roofline).

WHY: XLA's ``cost_analysis()`` counts a ``while`` (lax.scan) body ONCE,
not × trip-count — with scan-over-layers every per-layer FLOP/byte/
collective is undercounted by ~n_layers.  The dry-run JSONs carry those
raw numbers (kept for reference); the roofline table is built from this
analytic model, which we cross-checked against unrolled-scan compiles
of reduced-depth variants (see EXPERIMENTS.md §Roofline).

All quantities are PER DEVICE PER STEP.  Mesh: dp = pod·data (batch
axes), tp = model.  Conventions:

- matmul flops = 2·m·n·k;   train executes fwd + bwd(2×fwd) + remat
  re-fwd (1×fwd) = 4× fwd flops.
- bytes: HBM traffic ≈ 3 passes (fwd/bwd/remat) × (param reads +
  activation rw) + optimizer update (read p,mu,nu + write) + score
  matrices in f32.
- collectives: TP all-reduces 2 per layer (attn-out, ffn-out) of the
  local activation slab, ring factor 2, ×3 passes; DP gradient
  reduce-scatter + param all-gather (FSDP) or grad all-reduce; EP
  all-to-all 2× (dispatch + return).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict

BF16 = 2
F32 = 4


@dataclasses.dataclass
class CellCost:
    flops: float  # per device
    hbm_bytes: float
    coll_bytes: float
    useful_flops: float  # MODEL_FLOPS (6ND / 2ND) per device

    def terms(self, peak=197e12, hbm=819e9, ici=50e9) -> Dict[str, float]:
        return {
            "compute": self.flops / peak,
            "memory": self.hbm_bytes / hbm,
            "collective": self.coll_bytes / ici,
        }


def _mesh_sizes(mesh_kind: str):
    return (32, 16) if mesh_kind == "multi" else (16, 16)  # (dp, tp)


def _attn_flops_fwd(cfg, B, S, T, causal_frac=0.5):
    """scores + AV for one layer, full batch (global)."""
    a = cfg.attn
    if a is None:
        return 0.0
    H, hd = a.n_heads, a.head_dim
    if a.mla is not None:  # latent attention: scores vs kv_lora + rope
        m = a.mla
        return 2.0 * B * H * S * T * causal_frac * (2 * m.kv_lora + m.rope_dim) / 2
    return 4.0 * B * H * hd * S * T * causal_frac


def _per_layer_linear_params(cfg, layer_type: str) -> float:
    """Matmul params in one layer of the given type."""
    D = cfg.d_model
    a, f, m, s, xl_ = cfg.attn, cfg.ffn, cfg.moe, cfg.ssm, cfg.xlstm
    if layer_type in ("attn", "local", "shared_attn", "attn_moe"):
        if a.mla is not None:
            ml = a.mla
            attn_p = (D * ml.q_lora + ml.q_lora * a.n_heads * (ml.nope_dim + ml.rope_dim)
                      + D * ml.kv_lora + ml.kv_lora * a.n_heads * (ml.nope_dim + ml.v_dim)
                      + D * ml.rope_dim + a.n_heads * ml.v_dim * D)
        else:
            attn_p = D * a.n_heads * a.head_dim * 2 + D * a.n_kv * a.head_dim * 2
        if layer_type == "attn_moe":
            mo = m
            ffn_p = mo.top_k * 3 * D * mo.d_ff_expert + 3 * D * (mo.d_ff_shared or 0)
        else:
            ffn_p = (3 if f.gated else 2) * D * f.d_ff
        return attn_p + ffn_p
    if layer_type == "mamba":
        di = s.expand * D
        H = di // s.head_dim
        return D * (2 * di + 2 * s.d_state + H) + di * D
    if layer_type == "mlstm":
        di = int(xl_.proj_factor_m * D)
        di -= di % xl_.n_heads
        return D * 2 * di + 3 * di * di + di * 2 * xl_.n_heads + di * D
    if layer_type == "slstm":
        dff = int(xl_.proj_factor_s * D)
        return D * 4 * D + 4 * xl_.n_heads * (D // xl_.n_heads) ** 2 + 3 * D * dff
    raise ValueError(layer_type)


def _linear_params_total(cfg) -> float:
    total = sum(_per_layer_linear_params(cfg, t) for t in cfg.pattern())
    if cfg.kind == "encdec":
        # encoder layers: attn + ungated mlp
        enc = cfg.n_enc_layers * (
            4 * cfg.d_model * cfg.attn.n_heads * cfg.attn.head_dim
            + 2 * cfg.d_model * cfg.ffn.d_ff
        )
        # decoder cross-attention on top of the decoder self-attn+mlp
        cross = cfg.n_layers * 4 * cfg.d_model * cfg.attn.n_heads * cfg.attn.head_dim
        total += enc + cross
    return total


def _resident_param_bytes(cfg) -> float:
    from benchmarks.roofline import _param_counts

    total, _ = _param_counts(cfg.name.replace("_", "-"))
    return total * BF16


def _active_linear_params(cfg) -> float:
    return _linear_params_total(cfg)


def analytic_cell(arch: str, cfg, shape_name: str, mesh_kind: str,
                  *, overrides: dict | None = None) -> CellCost:
    """overrides: {'f32_scores': bool, 'fsdp': bool, 'remat_passes': float,
    'flash': bool} — used by §Perf to model candidate optimizations."""
    o = {"f32_scores": True, "remat_passes": 3.0, "flash": False, "policy": "2d"}
    o.update(overrides or {})
    dp, tp = _mesh_sizes(mesh_kind)
    if o["policy"] == "dp":
        dp, tp = dp * tp, 1  # model axis joins the batch axes
    n_dev = dp * tp
    D, V = cfg.d_model, cfg.vocab
    Lp = cfg.pattern()
    lin_p = _active_linear_params(cfg)
    from benchmarks.roofline import _param_counts
    total_p, active_p = _param_counts(arch)

    SHAPES = {"train_4k": (256, 4096), "prefill_32k": (32, 32_768),
              "decode_32k": (128, 32_768), "long_500k": (1, 524_288)}
    B, S = SHAPES[shape_name]
    kind = ("train" if shape_name == "train_4k"
            else "prefill" if shape_name == "prefill_32k" else "decode")

    fsdp = o.get("fsdp", 5 * total_p * BF16 / tp > 8 * 2**30)
    passes = 1.0 + o["remat_passes"] if kind == "train" else 1.0  # fwd + (bwd 2 + remat 1)

    # ---------------- flops ----------------
    if kind in ("train", "prefill"):
        tokens = B * S
        fwd_lin = 2.0 * lin_p * tokens + 2.0 * tokens * D * V  # + logits
        fwd_attn = 0.0
        for t in Lp:
            if t in ("attn", "shared_attn", "attn_moe"):
                fwd_attn += _attn_flops_fwd(cfg, B, S, S)
            elif t == "local":
                w = min(cfg.attn.window or S, S)
                fwd_attn += _attn_flops_fwd(cfg, B, S, w, causal_frac=1.0)
            elif t == "mlstm":
                xl_ = cfg.xlstm
                di = int(xl_.proj_factor_m * D)
                fwd_attn += 4.0 * B * di * S * S * 0.5  # quadratic mLSTM form
            elif t == "mamba":
                s_ = cfg.ssm
                di = s_.expand * D
                fwd_attn += tokens * (4.0 * di * s_.d_state + 4.0 * di * s_.chunk * 0.5)
            elif t == "slstm":
                pass  # linear terms already counted; recurrence is O(D) elementwise
        if cfg.kind == "encdec":
            from repro.configs.whisper_small import N_FRAMES
            fwd_attn += cfg.n_enc_layers * _attn_flops_fwd(cfg, B, N_FRAMES, N_FRAMES, 1.0)
            fwd_attn += cfg.n_layers * _attn_flops_fwd(cfg, B, S, N_FRAMES, 1.0)
        flops_g = (fwd_lin + fwd_attn) * passes
        useful_g = (6.0 if kind == "train" else 2.0) * active_p * tokens
    else:  # decode: one token, cache length S
        tokens = B
        fwd_lin = 2.0 * lin_p * tokens + 2.0 * tokens * D * V
        fwd_attn = 0.0
        for t in Lp:
            if t in ("attn", "shared_attn", "attn_moe"):
                fwd_attn += _attn_flops_fwd(cfg, B, 1, S, causal_frac=1.0)
            elif t == "local":
                fwd_attn += _attn_flops_fwd(cfg, B, 1, min(cfg.attn.window or S, S), 1.0)
            elif t == "mamba":
                s_ = cfg.ssm
                di = s_.expand * D
                fwd_attn += tokens * 4.0 * di * s_.d_state
            elif t == "mlstm":
                xl_ = cfg.xlstm
                di = int(xl_.proj_factor_m * D)
                P = di // xl_.n_heads
                fwd_attn += tokens * 4.0 * di * P
        if cfg.kind == "encdec":
            from repro.configs.whisper_small import N_FRAMES
            fwd_attn += cfg.n_layers * _attn_flops_fwd(cfg, B, 1, N_FRAMES, 1.0)
        flops_g = fwd_lin + fwd_attn
        useful_g = 2.0 * active_p * tokens
    flops = flops_g / n_dev
    useful = useful_g / n_dev

    # ---------------- hbm bytes ----------------
    toks_dev = tokens / dp if kind != "decode" else max(tokens / dp, 1)
    if kind in ("train", "prefill"):
        param_reads = passes * lin_p * BF16 / tp
        act_rw = 8.0 * len(Lp) * toks_dev * D * BF16  # residual stream rw / layer
        score_bytes = 0.0
        sb = F32 if o["f32_scores"] else BF16
        if not o["flash"] and cfg.attn is not None:
            H = cfg.attn.n_heads
            for t in Lp:
                if t in ("attn", "shared_attn", "attn_moe"):
                    score_bytes += 3.0 * (B / dp) * (H / tp) * S * S * sb
                elif t == "local":
                    w = min(cfg.attn.window or S, S)
                    score_bytes += 3.0 * (B / dp) * (H / tp) * S * w * sb
        logits_bytes = 3.0 * toks_dev * (V / tp) * F32 / 8  # chunked CE (8 chunks live 1)
        opt_bytes = 0.0
        if kind == "train":
            shard_div = tp * (dp if fsdp else 1)
            mdt = BF16 if total_p > 3e11 else F32
            opt_bytes = total_p * (2 * BF16 + 4 * mdt) / shard_div  # p rw + mu,nu rw
            grad_bytes = total_p * F32 / shard_div * 2
            opt_bytes += grad_bytes
        hbm = param_reads + act_rw + score_bytes + logits_bytes + opt_bytes
    else:
        # decode: weight-bound — weights are read IN PLACE on their
        # shard (EP/TP: tokens travel to weights, never the reverse),
        # so per-device reads = the resident shard
        param_reads = total_p * BF16 / (tp * (dp if fsdp else 1))
        cache = 0.0
        a = cfg.attn
        for t in Lp:
            if t in ("attn", "shared_attn", "attn_moe") and a is not None:
                if a.mla is not None:
                    cache += B * S * (a.mla.kv_lora + a.mla.rope_dim) * BF16
                else:
                    cache += 2 * B * S * a.n_kv * a.head_dim * BF16
            elif t == "local" and a is not None:
                cache += 2 * B * min(a.window or S, S) * a.n_kv * a.head_dim * BF16
        cache /= n_dev  # cache is sharded over batch/heads or seq
        act = 4.0 * len(Lp) * (B / min(dp, max(B, 1)) if B >= dp else B) * D * BF16
        hbm = param_reads + cache + act

    # ---------------- collective bytes ----------------
    coll = 0.0
    if tp > 1:
        # TP: 2 all-reduces per layer over the local activation slab
        slab = toks_dev * D * BF16
        coll += 2.0 * 2.0 * len(Lp) * slab * (passes if kind == "train" else 1.0) * (tp - 1) / tp
    if kind == "train":
        if o["policy"] == "dp":
            # pure FSDP: all-gather params each pass + reduce-scatter grads
            coll += (passes + 1.0) * total_p * BF16
        elif fsdp:
            # reduce-scatter grads + all-gather params (per pass)
            coll += 2.0 * total_p * BF16 / tp
        else:
            coll += 2.0 * total_p * F32 / tp  # ring all-reduce grads
    if cfg.moe is not None and kind != "decode":
        mo = cfg.moe
        n_moe = sum(1 for t in Lp if t == "attn_moe")
        # dispatch groups spread tokens over the WHOLE mesh (dp·tp) —
        # see ffn.moe_forward; a2a volume per device is tokens/(dp·tp)
        a2a = tokens / n_dev * mo.top_k * D * BF16 * mo.capacity_factor
        coll += 2.0 * n_moe * a2a * (passes if kind == "train" else 1.0)
    elif cfg.moe is not None:
        mo = cfg.moe
        n_moe = sum(1 for t in Lp if t == "attn_moe")
        coll += 2.0 * n_moe * (B / dp if B >= dp else B) * mo.top_k * D * BF16

    return CellCost(flops=flops, hbm_bytes=hbm, coll_bytes=coll, useful_flops=useful)


def analytic_cca(shape_name: str, mesh_kind: str = "single",
                 *, microbatch: int = 4096, chunk_rows: int | None = None,
                 int8_psum: bool = False, overlap: bool = False) -> CellCost:
    """Cost model for the paper's own workload: one full CCA data pass
    (Europarl scale: n=1.24M, da=db=2^19, k̃=2060, bf16 compute).

    Knobs mirror the implementation: ``microbatch`` (rows per scan step
    on each device — sets Q re-read and accumulator-rw frequency),
    ``int8_psum`` (compressed Y reduction, distributed/compress.py),
    ``overlap`` (bucketed psum hidden under compute → collective term
    only counts the un-overlappable remainder).
    """
    dp, tp = _mesh_sizes(mesh_kind)
    n_dev = dp * tp
    n, d, kt = 1_235_976, 2**19, 2060
    rows_dev = n / dp
    d_loc = d / tp
    n_mb = max(1.0, rows_dev / microbatch)

    final = "final" in shape_name
    # power pass: project (X·Q) + accumulate (XᵀP), two views.
    # final pass: project only + small (k̃×k̃) grams.
    flops_g = (4.0 if final else 8.0) * n * d * kt + (6.0 * n * kt * kt if final else 0)
    flops = flops_g / n_dev
    useful = flops  # every data-pass flop is algorithmic (no remat/waste)

    # hbm per device: stream X once + Q re-read per microbatch + Y rw per mb
    x_bytes = 2.0 * rows_dev * d_loc * BF16  # A and B local slabs
    q_bytes = 2.0 * n_mb * d_loc * kt * BF16
    y_bytes = 0.0 if final else 2.0 * 2.0 * n_mb * d_loc * kt * F32  # rw per mb
    c_bytes = (3.0 * 2.0 * n_mb * kt * kt * F32) if final else 0.0
    p_bytes = 2.0 * rows_dev * kt * F32  # projected activations rw
    hbm = x_bytes + q_bytes + y_bytes + c_bytes + p_bytes

    # collectives: per-mb psum of projected (mb, k̃) over model +
    # one end-of-pass psum of the accumulators over rows
    per_mb = 2.0 * n_mb * microbatch * kt * F32 * 2 * (tp - 1) / tp
    acc = (3.0 * kt * kt) if final else (2.0 * d_loc * kt)
    y_psum = acc * (1 if int8_psum else 4) * 2 * (dp - 1) / dp
    coll = per_mb + y_psum
    if overlap:
        # bucketed accumulate-then-psum: the Y reduction rides under the
        # next microbatches' compute; only the last bucket is exposed
        coll = per_mb + y_psum / 8
    return CellCost(flops=flops, hbm_bytes=hbm, coll_bytes=coll, useful_flops=useful)


def analyze(arch: str, shape_name: str, mesh_kind: str = "single",
            overrides: dict | None = None) -> dict:
    from repro.configs import get_config

    if arch == "europarl-cca":
        c = analytic_cca(shape_name, mesh_kind, **(overrides or {}))
    else:
        cfg = get_config(arch)
        c = analytic_cell(arch, cfg, shape_name, mesh_kind, overrides=overrides)
    t = c.terms()
    dom = max(t, key=t.get)
    step = max(t.values())
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "t_compute_s": t["compute"], "t_memory_s": t["memory"],
        "t_collective_s": t["collective"], "dominant": dom,
        "step_time_s": step,
        "useful_flop_ratio": c.useful_flops / c.flops if c.flops else 0.0,
        "roofline_frac": (c.useful_flops / 197e12) / step if step else 0.0,
    }
