"""Shared benchmark helpers: timing, the Europarl stand-in corpus, and
the one BENCH artifact writer (schema + commit metadata stamp)."""

from __future__ import annotations

import json
import os
import subprocess
import time

import jax
import jax.numpy as jnp

from repro.data import PlantedCCAData

BENCH_SCHEMA = 1


def time_us(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_meta() -> dict:
    """Provenance stamp for a BENCH artifact: commit, time, backend.

    Every field is best-effort — benchmarks must run from a tarball
    (no git) just as well as from a checkout."""
    meta = {
        "when": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "backend": jax.default_backend(),
        "jax": jax.__version__,
    }
    try:
        meta["commit"] = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        meta["commit"] = None
    return meta


def write_bench(bench: dict, out_path: str) -> dict:
    """The single BENCH write path: stamp ``schema`` + ``meta``, write
    the json, print the grep-able ``BENCH`` line, and — when the
    artifact lands in a ``results/`` directory — refold that
    directory's trajectory (``results/TRAJECTORY.json``) so every
    committed BENCH file stays part of one comparable record."""
    bench = dict(bench)
    bench.setdefault("schema", BENCH_SCHEMA)
    bench.setdefault("meta", bench_meta())
    out_dir = os.path.dirname(out_path) or "."
    os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2)
        f.write("\n")
    print("BENCH " + json.dumps(bench))
    if os.path.basename(os.path.abspath(out_dir)) == "results" and \
            os.path.basename(out_path).startswith("BENCH_"):
        from repro.obs import trajectory
        trajectory.write(out_dir)
    return bench


def europarl_standin(n=6000, da=96, db=80, rank=48, seed=0):
    """Planted power-law corpus with a train/test split (paper §4 setup,
    scaled to CPU)."""
    d = PlantedCCAData(n=n, da=da, db=db, rank=rank, decay=0.8, noise=0.5,
                       seed=seed, chunk=max(256, n // 8))
    A, B = d.materialize()
    n_tr = int(n * 0.9)
    return (jnp.asarray(A[:n_tr]), jnp.asarray(B[:n_tr]),
            jnp.asarray(A[n_tr:]), jnp.asarray(B[n_tr:]))
