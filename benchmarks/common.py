"""Shared benchmark helpers: timing + the Europarl stand-in corpus."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.data import PlantedCCAData


def time_us(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def europarl_standin(n=6000, da=96, db=80, rank=48, seed=0):
    """Planted power-law corpus with a train/test split (paper §4 setup,
    scaled to CPU)."""
    d = PlantedCCAData(n=n, da=da, db=db, rank=rank, decay=0.8, noise=0.5,
                       seed=seed, chunk=max(256, n // 8))
    A, B = d.materialize()
    n_tr = int(n * 0.9)
    return (jnp.asarray(A[:n_tr]), jnp.asarray(B[:n_tr]),
            jnp.asarray(A[n_tr:]), jnp.asarray(B[n_tr:]))
