"""Block/bucket-size autotune sweep for the fused data-pass kernels.

    PYTHONPATH=src python -m benchmarks.sweep_blocks
    make sweep-blocks

Sweeps the autotune candidates for ``op="powerpass"`` and
``op="projgram"`` (see repro.kernels.autotune) over a set of chunk
shapes, persists the winning (block_n, block_contraction, bucket) caps
to the autotune cache, then times the staged (P-reuse) vs. recompute
schedules for each shape (``op="powerpass-staged"`` /
``op="projgram-staged"`` schedule entries), and finally emits the
bucketed-kernel BENCH json (``results/BENCH_bucketed.json``) via
:func:`benchmarks.kernel_bench.bucketed_report`.

The default shapes are CPU-interpret-feasible stand-ins that cross the
old 2^20 fused-block threshold; ``--europarl`` sweeps the paper's real
chunk shape (8192 × 2^19, k̃ = 2060) — run that on the TPU target,
where the timings are Mosaic, not interpreter emulation, and commit the
resulting cache (see ROADMAP).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.kernels import autotune

# (n, da, db, k̃) power-pass chunk shapes; the projgram sweep reuses
# (n, da, k̃).  Both defaults cross the old single-block VMEM limit
# while staying small enough for CPU interpret mode — production
# shapes belong on the TPU target (--europarl).
DEFAULT_SHAPES = [
    (256, 4096, 384, 256),
    (256, 1 << 13, 256, 1024),
]
EUROPARL_SHAPE = (8192, 1 << 19, 1 << 19, 2060)


def sweep(shapes, iters: int = 2) -> list[dict]:
    results = []
    for n, da, db, kt in shapes:
        # zeros suffice — block timing is data-independent
        a = jnp.zeros((n, da), jnp.float32)
        b = jnp.zeros((n, db), jnp.float32)
        qb = jnp.zeros((db, kt), jnp.float32)
        qa = jnp.zeros((da, kt), jnp.float32)
        pp = autotune.autotune_powerpass(a, b, qb, iters=iters)
        print(f"[sweep] powerpass n={n} da={da} db={db} kt={kt} -> blocks={pp}")
        pg = autotune.autotune_projgram(a, qa, iters=iters)
        print(f"[sweep] projgram  n={n} d={da} kt={kt} -> blocks={pg}")
        if da != db:
            # the drivers call both view directions — distinct cache keys
            pp_b = autotune.autotune_powerpass(b, a, qa, iters=iters)
            print(f"[sweep] powerpass n={n} da={db} db={da} kt={kt} -> blocks={pp_b}")
            pg_b = autotune.autotune_projgram(b, qb, iters=iters)
            print(f"[sweep] projgram  n={n} d={db} kt={kt} -> blocks={pg_b}")
        else:
            pp_b, pg_b = pp, pg
        # schedule sweep: time staged (P-reuse) vs recompute and persist
        # the winner so choose_*_schedule prefers measurement over the
        # analytic roofline crossover
        sched_pp = autotune.autotune_powerpass_staged(a, b, qb, iters=iters)
        print(f"[sweep] powerpass schedule n={n} da={da} db={db} kt={kt} "
              f"-> {sched_pp}")
        sched_pg = autotune.autotune_projgram_staged(a, qa, iters=iters)
        print(f"[sweep] projgram  schedule n={n} d={da} kt={kt} -> {sched_pg}")
        results.append({"shape": [n, da, db, kt],
                        "powerpass_blocks": list(pp),
                        "powerpass_blocks_b": list(pp_b),
                        "projgram_blocks": list(pg),
                        "projgram_blocks_b": list(pg_b),
                        "powerpass_schedule": sched_pp,
                        "projgram_schedule": sched_pg})
    return results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="results/BENCH_bucketed.json")
    ap.add_argument("--iters", type=int, default=2)
    ap.add_argument("--europarl", action="store_true",
                    help="sweep the paper's real chunk shape (needs ~TPU-"
                         "scale memory; the default shapes run anywhere)")
    args = ap.parse_args(argv)

    shapes = [EUROPARL_SHAPE] if args.europarl else DEFAULT_SHAPES
    sweep(shapes, iters=args.iters)
    print(f"[sweep] cache: {autotune.cache_path()} "
          f"(backend={jax.default_backend()})")

    from .kernel_bench import bucketed_report

    bucketed_report(args.out)


if __name__ == "__main__":
    main()
