"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh):
  compute term    = HLO_FLOPs / peak_FLOP/s          (per-chip: post-SPMD
  memory term     = HLO_bytes / HBM_bw                HLO shapes are local)
  collective term = collective_bytes / ICI_bw
plus MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params,
and the MODEL/HLO FLOP ratio (useful-compute fraction).

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import glob
import json
import os

import jax

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_PARAM_CACHE: dict = {}


def _param_counts(arch: str):
    """(total params, active params per token) for an arch."""
    if arch in _PARAM_CACHE:
        return _PARAM_CACHE[arch]
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    total = sum(x.size for x in jax.tree.leaves(shapes))
    active = total
    if cfg.moe is not None:
        m = cfg.moe
        n_moe_layers = sum(1 for t in cfg.pattern() if t == "attn_moe")
        expert_params = n_moe_layers * m.n_experts * 3 * cfg.d_model * m.d_ff_expert
        active = total - expert_params * (1 - m.top_k / m.n_experts)
    _PARAM_CACHE[arch] = (total, active)
    return total, active


def model_flops(arch: str, shape: str, kind: str, batch: int, seq: int) -> float:
    """Global MODEL_FLOPS for one step (6ND train, 2ND inference)."""
    _, active = _param_counts(arch)
    if kind == "train":
        tokens = batch * seq
        return 6.0 * active * tokens
    if kind == "prefill":
        return 2.0 * active * batch * seq
    return 2.0 * active * batch  # decode: one token per sequence


SHAPE_META = {
    "train_4k": ("train", 256, 4096),
    "prefill_32k": ("prefill", 32, 32_768),
    "decode_32k": ("decode", 128, 1),
    "long_500k": ("decode", 1, 1),
}


def analyze_cell(d: dict) -> dict:
    """Roofline terms for one dry-run cell.

    PRIMARY terms come from the analytic per-device cost model
    (benchmarks.analytic): XLA cost_analysis counts lax.scan bodies
    once, undercounting per-layer flops/bytes/collectives by ~n_layers,
    so the HLO numbers are attached as `hlo_*` reference fields only.
    Memory feasibility (arg/temp bytes) is taken from the compiled
    artifact, which IS scan-aware.
    """
    from benchmarks import analytic

    arch, shape = d["arch"], d["shape"]
    shape_key = shape if arch != "europarl-cca" else shape.replace("cca_", "") + ""
    out = analytic.analyze(arch, shape, d["mesh"])
    out["devices"] = d["devices"]
    out["hlo_flops"] = d.get("flops", 0.0)
    out["hlo_bytes"] = d.get("bytes_accessed", 0.0)
    out["hlo_collective_bytes"] = d.get("collectives", {}).get("total_bytes", 0)
    out["memory"] = d.get("memory", {})
    return out


def load_cells(result_dir: str = "results/dryrun"):
    cells = []
    for f in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        d = json.load(open(f))
        if d.get("status") == "ok":
            cells.append(analyze_cell(d))
        elif d.get("status") == "skipped":
            cells.append({"arch": d["arch"], "shape": d["shape"],
                          "mesh": d["mesh"], "skipped": d["reason"]})
    return cells


def roofline_rows(rows, result_dir: str = "results/dryrun"):
    cells = load_cells(result_dir)
    if not cells:
        rows.append(("roofline", 0.0, f"no dry-run artifacts in {result_dir} — "
                     "run python -m repro.launch.dryrun first"))
        return
    for c in cells:
        if "skipped" in c:
            rows.append((f"roofline_{c['arch']}_{c['shape']}_{c['mesh']}", 0.0,
                         f"SKIP({c['skipped'][:40]})"))
            continue
        rows.append((
            f"roofline_{c['arch']}_{c['shape']}_{c['mesh']}",
            c["step_time_s"] * 1e6,
            f"dom={c['dominant']} comp={c['t_compute_s']:.3g}s "
            f"mem={c['t_memory_s']:.3g}s coll={c['t_collective_s']:.3g}s "
            + (f"useful={c.get('useful_flop_ratio', 0):.2f} "
               f"roofline_frac={c.get('roofline_frac', 0):.3f}"
               if "useful_flop_ratio" in c else ""),
        ))


def write_markdown(result_dir: str = "results/dryrun",
                   out_path: str = "results/roofline.md") -> str:
    cells = load_cells(result_dir)
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
        "| dominant | useful FLOP ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if "skipped" in c:
            lines.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — | — "
                         f"| skipped: {c['skipped']} | — | — |")
        else:
            lines.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} "
                f"| {c['t_compute_s']:.4g} | {c['t_memory_s']:.4g} "
                f"| {c['t_collective_s']:.4g} | **{c['dominant']}** "
                f"| {c.get('useful_flop_ratio', float('nan')):.2f} "
                f"| {c.get('roofline_frac', float('nan')):.3f} |"
            )
    md = "\n".join(lines)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        f.write(md + "\n")
    return md
