"""Pallas kernel micro-benchmarks (interpret mode on CPU — numbers are
CPU-emulation timings; the real signal is the allclose check and the
derived arithmetic-intensity / roofline terms for the TPU target)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, pallas_matmul, projgram, ref

from .common import time_us

PEAK_FLOPS = 197e12  # bf16 TPU v5e
HBM_BW = 819e9


def kernel_benchmarks(rows):
    key = jax.random.PRNGKey(0)
    n, d, kt = 2048, 1024, 512
    x = jax.random.normal(key, (n, d), jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(1), (d, kt), jnp.float32)

    # project (P = XQ)
    us = time_us(lambda: pallas_matmul(x, q, interpret=True))
    flops = 2 * n * d * kt
    byts = 4 * (n * d + d * kt + n * kt)
    ai = flops / byts
    t_tpu = max(flops / PEAK_FLOPS, byts / HBM_BW) * 1e6
    rows.append(("kernel_project_2048x1024x512", us,
                 f"AI={ai:.1f}flops/B tpu_roofline_us={t_tpu:.1f}"))

    # tall-skinny update (Y += XᵀP)
    p = jax.random.normal(jax.random.PRNGKey(2), (n, kt), jnp.float32)
    us = time_us(lambda: pallas_matmul(x, p, transpose_lhs=True, interpret=True))
    rows.append(("kernel_tn_update_1024x2048x512", us,
                 f"AI={2*n*d*kt/(4*(n*d+n*kt+d*kt)):.1f}flops/B"))

    # fused projgram
    us = time_us(lambda: projgram(x, q, interpret=True))
    fused_flops = 2 * n * d * kt + 2 * n * kt * kt
    fused_bytes = 4 * (n * d + d * kt + n * kt + kt * kt)
    rows.append(("kernel_projgram_fused", us,
                 f"AI={fused_flops/fused_bytes:.1f}flops/B "
                 f"(unfused_AI={2*n*d*kt/(4*(n*d+d*kt+2*n*kt)):.1f})"))

    # full fused final-pass chunk
    b = jax.random.normal(jax.random.PRNGKey(3), (n, d // 2), jnp.float32)
    qb = jax.random.normal(jax.random.PRNGKey(4), (d // 2, kt), jnp.float32)
    us = time_us(lambda: ops.final_pass_chunk(x, b, q, qb, interpret=True))
    rows.append(("kernel_final_pass_chunk", us, "Ca+Cb+F one X/B read each"))
