"""Pallas kernel micro-benchmarks (interpret mode on CPU — numbers are
CPU-emulation timings; the real signal is the allclose check and the
derived arithmetic-intensity / roofline terms for the TPU target).

Also emits a BENCH json comparing the two data-pass engines (fused
Pallas kernels vs the pure-jnp oracle path) per chunk op:

    PYTHONPATH=src python -m benchmarks.kernel_bench --out results/kernel_bench.json

and, via :func:`bucketed_report` (also driven by
``benchmarks/sweep_blocks.py`` / ``make sweep-blocks``), a
BENCH_bucketed json for the column-bucketed fused kernels: timings on a
past-threshold shape plus the traced pallas_call count of the paper's
Europarl-scale chunk — the HBM-read regression guard (2 fused calls per
power-pass chunk under the recompute schedule, no unfused fallback).
:func:`staged_report` (BENCH_staged.json) tracks the staged (P-reuse)
schedule: bitwise parity vs recompute, the Europarl auto-schedule
choice, and the modelled-FLOP drop from n_buckets·proj + acc to
proj + acc.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.kernels import ops, pallas_matmul, projgram, ref

from .common import time_us, write_bench

PEAK_FLOPS = 197e12  # bf16 TPU v5e
HBM_BW = 819e9


def kernel_benchmarks(rows):
    key = jax.random.PRNGKey(0)
    n, d, kt = 2048, 1024, 512
    x = jax.random.normal(key, (n, d), jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(1), (d, kt), jnp.float32)

    # project (P = XQ)
    us = time_us(lambda: pallas_matmul(x, q, interpret=True))
    flops = 2 * n * d * kt
    byts = 4 * (n * d + d * kt + n * kt)
    ai = flops / byts
    t_tpu = max(flops / PEAK_FLOPS, byts / HBM_BW) * 1e6
    rows.append(("kernel_project_2048x1024x512", us,
                 f"AI={ai:.1f}flops/B tpu_roofline_us={t_tpu:.1f}"))

    # tall-skinny update (Y += XᵀP)
    p = jax.random.normal(jax.random.PRNGKey(2), (n, kt), jnp.float32)
    us = time_us(lambda: pallas_matmul(x, p, transpose_lhs=True, interpret=True))
    rows.append(("kernel_tn_update_1024x2048x512", us,
                 f"AI={2*n*d*kt/(4*(n*d+n*kt+d*kt)):.1f}flops/B"))

    # fused projgram
    us = time_us(lambda: projgram(x, q, interpret=True))
    fused_flops = 2 * n * d * kt + 2 * n * kt * kt
    fused_bytes = 4 * (n * d + d * kt + n * kt + kt * kt)
    rows.append(("kernel_projgram_fused", us,
                 f"AI={fused_flops/fused_bytes:.1f}flops/B "
                 f"(unfused_AI={2*n*d*kt/(4*(n*d+d*kt+2*n*kt)):.1f})"))

    # full fused final-pass chunk
    b = jax.random.normal(jax.random.PRNGKey(3), (n, d // 2), jnp.float32)
    qb = jax.random.normal(jax.random.PRNGKey(4), (d // 2, kt), jnp.float32)
    us = time_us(lambda: ops.final_pass_chunk(x, b, q, qb, interpret=True))
    rows.append(("kernel_final_pass_chunk", us, "Ca+Cb+F one X/B read each"))

    # fused power-pass chunk (2 pallas_calls; A/B one HBM read each)
    us = time_us(lambda: ops.power_pass_chunk(x, b, q, qb, interpret=True))
    rows.append(("kernel_power_pass_chunk", us, "dYa+dYb fused, P stays in VMEM"))


def engine_comparison(out_path: str = "results/kernel_bench.json",
                      rows: list | None = None) -> dict:
    """Time the per-chunk data-pass updates under both engines and write
    a BENCH json.  On CPU the kernel engine runs in interpret mode, so
    the jnp column wins on wall clock — the json's purpose is tracking
    both engines' timings per backend plus the max engine disagreement."""
    key = jax.random.PRNGKey(0)
    n, da, db, kt = 1024, 512, 384, 256
    a = jax.random.normal(key, (n, da), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, db), jnp.float32)
    qa = jax.random.normal(jax.random.PRNGKey(2), (da, kt), jnp.float32)
    qb = jax.random.normal(jax.random.PRNGKey(3), (db, kt), jnp.float32)

    power_jnp = jax.jit(ref.power_pass_ref)
    final_jnp = jax.jit(ref.final_pass_ref)
    cases = [
        ("power_pass_chunk", lambda: ops.power_pass_chunk(a, b, qa, qb),
         lambda: power_jnp(a, b, qa, qb)),
        ("final_pass_chunk", lambda: ops.final_pass_chunk(a, b, qa, qb),
         lambda: final_jnp(a, b, qa, qb)),
    ]
    results = []
    for name, run_k, run_j in cases:
        out_k = jax.tree.leaves(run_k())
        out_j = jax.tree.leaves(run_j())
        err = max(
            float(jnp.linalg.norm(gk - gj) / jnp.maximum(jnp.linalg.norm(gj), 1e-30))
            for gk, gj in zip(out_k, out_j)
        )
        us_k = time_us(run_k)
        us_j = time_us(run_j)
        results.append({"name": name, "shape": [n, da, db, kt],
                        "kernels_us": round(us_k, 1), "jnp_us": round(us_j, 1),
                        "max_rel_err": err})
        if rows is not None:
            rows.append((f"engine_{name}_kernels", us_k, f"rel_err_vs_jnp={err:.2e}"))
            rows.append((f"engine_{name}_jnp", us_j, "oracle path"))

    bench = {
        "bench": "cca_data_pass_engines",
        "backend": jax.default_backend(),
        "interpret": jax.default_backend() != "tpu",
        "results": results,
    }
    bench = write_bench(bench, out_path)
    return bench


def bucketed_report(out_path: str = "results/BENCH_bucketed.json",
                    rows: list | None = None) -> dict:
    """BENCH json for the column-bucketed fused kernels.

    Two parts: (1) run+time the bucketed powerpass/projgram on a
    past-threshold shape that is still CPU-interpret-feasible, checking
    parity against the jnp oracle; (2) trace (no compute) the paper's
    Europarl-scale chunk (8192 × 2^19, k̃ = 2060) and report its
    pallas_call count — 2 fused calls per power-pass chunk, same as the
    small-shape fused path, i.e. one HBM read of each view per update.
    """
    from repro.configs.europarl_cca import config as europarl_config
    from repro.kernels import autotune
    from repro.kernels.compat import count_pallas_calls
    from repro.kernels.matmul import _round_up
    from repro.kernels.ops import _default_interpret
    from repro.kernels.powerpass import power_project_accumulate
    from repro.kernels.powerpass import resolve_blocks as resolve_pp
    from repro.kernels.projgram import projgram as projgram_fused
    from repro.kernels.projgram import resolve_blocks as resolve_pg

    interpret = _default_interpret()  # Mosaic on TPU, interpreter elsewhere
    key = jax.random.PRNGKey(0)
    # dap·k̃p = 2^24 ≫ the 2^20 per-block budget → multiple ΔY buckets
    n, da, db, kt = 512, 1 << 14, 384, 1024
    a = jax.random.normal(key, (n, da), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, db), jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(2), (db, kt), jnp.float32)

    run = lambda: power_project_accumulate(a, b, q, interpret=interpret)
    got = run()
    want = ref.matmul_ref(a, ref.matmul_ref(b, q), transpose_lhs=True)
    err_pp = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    us_pp = time_us(run)
    # bucket count as the kernel actually resolved it (autotune cache
    # entries change it — don't hardcode what was timed)
    np_, dap = _round_up(n, 128), _round_up(da, 128)
    dbp, ktp = _round_up(db, 128), _round_up(kt, 128)
    caps = autotune.lookup("powerpass", np_, dbp, ktp, jnp.float32, extra=dap)
    buckets_pp = dap // resolve_pp(np_, dap, dbp, ktp, *caps)[2]

    # k̃ past the old 1024 projgram limit → multiple C-column buckets
    ktg = 2176
    qg = jax.random.normal(jax.random.PRNGKey(3), (db, ktg), jnp.float32)
    rung = lambda: projgram_fused(b, qg, interpret=interpret)
    p, c = rung()
    pw, cw = ref.projgram_ref(b, qg)
    err_pg = float(jnp.linalg.norm(c - cw) / jnp.linalg.norm(cw))
    us_pg = time_us(rung)
    caps = autotune.lookup("projgram", np_, dbp, ktg, jnp.float32)
    buckets_pg = ktg // resolve_pg(np_, dbp, ktg, *caps)[2]

    wl = europarl_config()
    skt = wl.rcca.sketch
    sds = jax.ShapeDtypeStruct
    # force the recompute schedule: this entry guards the FUSED call
    # count (one kernel per view); the staged schedule's counts live in
    # staged_report / BENCH_staged.json
    jaxpr = jax.make_jaxpr(
        lambda *xs: ops.power_pass_chunk(*xs, schedule="recompute",
                                         interpret=interpret))(
        sds((wl.chunk, wl.da), jnp.float32), sds((wl.chunk, wl.db), jnp.float32),
        sds((wl.da, skt), jnp.float32), sds((wl.db, skt), jnp.float32))
    europarl_calls = count_pallas_calls(jaxpr)
    jaxpr_f = jax.make_jaxpr(
        lambda *xs: ops.final_pass_chunk(*xs, schedule="recompute",
                                         interpret=interpret))(
        sds((wl.chunk, wl.da), jnp.float32), sds((wl.chunk, wl.db), jnp.float32),
        sds((wl.da, skt), jnp.float32), sds((wl.db, skt), jnp.float32))
    europarl_final_calls = count_pallas_calls(jaxpr_f)

    bench = {
        "bench": "cca_bucketed_fused_kernels",
        "backend": jax.default_backend(),
        "interpret": interpret,
        "results": [
            {"name": "powerpass_bucketed", "shape": [n, da, db, kt],
             "us": round(us_pp, 1), "rel_err_vs_jnp": err_pp,
             "buckets": buckets_pp},
            {"name": "projgram_bucketed", "shape": [n, db, ktg],
             "us": round(us_pg, 1), "rel_err_vs_jnp": err_pg,
             "buckets": buckets_pg},
            {"name": "power_pass_chunk_europarl_trace",
             "shape": [wl.chunk, wl.da, wl.db, skt],
             "pallas_calls": europarl_calls,
             "fused": europarl_calls == 2},
            {"name": "final_pass_chunk_europarl_trace",
             "shape": [wl.chunk, wl.da, wl.db, skt],
             "pallas_calls": europarl_final_calls,
             "fused": europarl_final_calls == 3},
        ],
    }
    bench = write_bench(bench, out_path)
    if rows is not None:
        rows.append(("bucketed_powerpass_16bkt", us_pp, f"rel_err={err_pp:.2e}"))
        rows.append(("bucketed_projgram_17bkt", us_pg, f"rel_err={err_pg:.2e}"))
    return bench


def seeded_report(out_path: str = "results/BENCH_seeded.json",
                  rows: list | None = None) -> dict:
    """Seeded-Ω vs materialized fused chunk updates: same block configs,
    so the outputs must agree BITWISE (the generator runs in-kernel on
    the very tiles the materialized path loads).  The json tracks both
    timings plus the Ω HBM residency the seeded path eliminates — on
    CPU interpret mode the in-kernel generation costs wall clock; the
    TPU trade is k̃·4 bytes of VMEM traffic per generated row against a
    (d, k̃) HBM read per bucket."""
    from repro.kernels import rand

    key = jax.random.PRNGKey(0)
    n, da, db, kt = 1024, 512, 384, 256
    a = jax.random.normal(key, (n, da), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, db), jnp.float32)
    seed_a = jnp.array([11, 12], jnp.uint32)
    seed_b = jnp.array([21, 22], jnp.uint32)
    qa = rand.dense_omega(seed_a, da, kt)
    qb = rand.dense_omega(seed_b, db, kt)

    cases = [
        ("power_pass_chunk",
         lambda: ops.power_pass_chunk_seeded(a, b, seed_a, seed_b,
                                             kt=kt, q_dtype=jnp.float32),
         lambda: ops.power_pass_chunk(a, b, qa, qb)),
        ("final_pass_chunk",
         lambda: ops.final_pass_chunk_seeded(a, b, seed_a, seed_b,
                                             kt=kt, q_dtype=jnp.float32),
         lambda: ops.final_pass_chunk(a, b, qa, qb)),
    ]
    omega_bytes = 4 * (da * kt + db * kt)
    results = []
    for name, run_s, run_m in cases:
        out_s = jax.tree.leaves(run_s())
        out_m = jax.tree.leaves(run_m())
        bitwise = all(bool(jnp.array_equal(gs, gm))
                      for gs, gm in zip(out_s, out_m))
        us_s = time_us(run_s)
        us_m = time_us(run_m)
        results.append({"name": name, "shape": [n, da, db, kt],
                        "seeded_us": round(us_s, 1),
                        "materialized_us": round(us_m, 1),
                        "bitwise_equal": bitwise,
                        "omega_hbm_bytes_saved": omega_bytes})
        if rows is not None:
            rows.append((f"seeded_{name}", us_s,
                         f"bitwise_equal={bitwise} "
                         f"omega_bytes_saved={omega_bytes}"))

    bench = {
        "bench": "cca_seeded_omega",
        "backend": jax.default_backend(),
        "interpret": jax.default_backend() != "tpu",
        "results": results,
    }
    bench = write_bench(bench, out_path)
    return bench


def staged_report(out_path: str = "results/BENCH_staged.json",
                  rows: list | None = None) -> dict:
    """BENCH json for the staged (P-reuse) powerpass schedule.

    Three parts: (1) time staged vs recompute on a CPU-feasible
    forced-bucket shape and assert they agree BITWISE (the staged
    schedule re-orders HBM traffic, never arithmetic); (2) trace the
    Europarl chunk and record the auto-chosen schedule + pallas_call
    counts per schedule; (3) the cost model's modelled chunk FLOPs for
    both schedules — the staged entry drops the n_buckets·proj
    recompute term, which is the optimization this file tracks.
    """
    from repro.configs.europarl_cca import config as europarl_config
    from repro.kernels.compat import count_pallas_calls
    from repro.kernels.ops import _default_interpret, chunk_cost
    from repro.kernels.powerpass import (choose_powerpass_schedule,
                                         power_project_accumulate)

    interpret = _default_interpret()
    key = jax.random.PRNGKey(0)
    # 16 ΔY buckets at block_da=256: plenty of P-reuse to measure
    n, da, db, kt = 256, 4096, 256, 512
    a = jax.random.normal(key, (n, da), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, db), jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(2), (db, kt), jnp.float32)

    run_s = lambda: power_project_accumulate(a, b, q, block_da=256,
                                             schedule="staged",
                                             interpret=interpret)
    run_r = lambda: power_project_accumulate(a, b, q, block_da=256,
                                             schedule="recompute",
                                             interpret=interpret)
    bitwise = bool(jnp.array_equal(run_s(), run_r()))
    assert bitwise, "staged schedule diverged from recompute"
    us_s, us_r = time_us(run_s), time_us(run_r)

    wl = europarl_config()
    skt = wl.rcca.sketch
    sds = jax.ShapeDtypeStruct
    chosen = choose_powerpass_schedule(wl.chunk, wl.da, wl.db, skt, "float32")
    structs = (sds((wl.chunk, wl.da), jnp.float32),
               sds((wl.chunk, wl.db), jnp.float32),
               sds((wl.da, skt), jnp.float32),
               sds((wl.db, skt), jnp.float32))
    calls = {}
    for sched in ("staged", "recompute"):
        jaxpr = jax.make_jaxpr(
            lambda *xs, _s=sched: ops.power_pass_chunk(
                *xs, schedule=_s, interpret=interpret))(*structs)
        calls[sched] = count_pallas_calls(jaxpr)

    chunk_cost.cache_clear()
    cost_s = chunk_cost("power", wl.chunk, wl.da, wl.db, skt, "float32",
                        engine="kernels", schedule="staged")
    cost_r = chunk_cost("power", wl.chunk, wl.da, wl.db, skt, "float32",
                        engine="kernels", schedule="recompute")
    flops_ratio = cost_r["flops"] / cost_s["flops"]

    bench = {
        "bench": "cca_staged_powerpass_schedule",
        "backend": jax.default_backend(),
        "interpret": interpret,
        "results": [
            {"name": "powerpass_staged_vs_recompute_16bkt",
             "shape": [n, da, db, kt],
             "staged_us": round(us_s, 1), "recompute_us": round(us_r, 1),
             "bitwise_equal": bitwise},
            {"name": "power_pass_chunk_europarl_schedule",
             "shape": [wl.chunk, wl.da, wl.db, skt],
             "auto_schedule": chosen,
             "pallas_calls": calls,
             "modelled_flops": {"staged": cost_s["flops"],
                                "recompute": cost_r["flops"]},
             "modelled_flops_ratio": round(flops_ratio, 1)},
        ],
    }
    bench = write_bench(bench, out_path)
    if rows is not None:
        rows.append(("staged_powerpass_16bkt", us_s,
                     f"bitwise={bitwise} recompute_us={us_r:.1f} "
                     f"europarl_flops_x{flops_ratio:.0f}"))
    return bench


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="results/kernel_bench.json")
    ap.add_argument("--bucketed-out", default="results/BENCH_bucketed.json")
    ap.add_argument("--seeded-out", default="results/BENCH_seeded.json")
    ap.add_argument("--staged-out", default="results/BENCH_staged.json")
    args = ap.parse_args(argv)
    rows: list = []
    kernel_benchmarks(rows)
    engine_comparison(args.out, rows)
    bucketed_report(args.bucketed_out, rows)
    seeded_report(args.seeded_out, rows)
    staged_report(args.staged_out, rows)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
