"""Pallas kernel micro-benchmarks (interpret mode on CPU — numbers are
CPU-emulation timings; the real signal is the allclose check and the
derived arithmetic-intensity / roofline terms for the TPU target).

Also emits a BENCH json comparing the two data-pass engines (fused
Pallas kernels vs the pure-jnp oracle path) per chunk op:

    PYTHONPATH=src python -m benchmarks.kernel_bench --out results/kernel_bench.json
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro.kernels import ops, pallas_matmul, projgram, ref

from .common import time_us

PEAK_FLOPS = 197e12  # bf16 TPU v5e
HBM_BW = 819e9


def kernel_benchmarks(rows):
    key = jax.random.PRNGKey(0)
    n, d, kt = 2048, 1024, 512
    x = jax.random.normal(key, (n, d), jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(1), (d, kt), jnp.float32)

    # project (P = XQ)
    us = time_us(lambda: pallas_matmul(x, q, interpret=True))
    flops = 2 * n * d * kt
    byts = 4 * (n * d + d * kt + n * kt)
    ai = flops / byts
    t_tpu = max(flops / PEAK_FLOPS, byts / HBM_BW) * 1e6
    rows.append(("kernel_project_2048x1024x512", us,
                 f"AI={ai:.1f}flops/B tpu_roofline_us={t_tpu:.1f}"))

    # tall-skinny update (Y += XᵀP)
    p = jax.random.normal(jax.random.PRNGKey(2), (n, kt), jnp.float32)
    us = time_us(lambda: pallas_matmul(x, p, transpose_lhs=True, interpret=True))
    rows.append(("kernel_tn_update_1024x2048x512", us,
                 f"AI={2*n*d*kt/(4*(n*d+n*kt+d*kt)):.1f}flops/B"))

    # fused projgram
    us = time_us(lambda: projgram(x, q, interpret=True))
    fused_flops = 2 * n * d * kt + 2 * n * kt * kt
    fused_bytes = 4 * (n * d + d * kt + n * kt + kt * kt)
    rows.append(("kernel_projgram_fused", us,
                 f"AI={fused_flops/fused_bytes:.1f}flops/B "
                 f"(unfused_AI={2*n*d*kt/(4*(n*d+d*kt+2*n*kt)):.1f})"))

    # full fused final-pass chunk
    b = jax.random.normal(jax.random.PRNGKey(3), (n, d // 2), jnp.float32)
    qb = jax.random.normal(jax.random.PRNGKey(4), (d // 2, kt), jnp.float32)
    us = time_us(lambda: ops.final_pass_chunk(x, b, q, qb, interpret=True))
    rows.append(("kernel_final_pass_chunk", us, "Ca+Cb+F one X/B read each"))

    # fused power-pass chunk (2 pallas_calls; A/B one HBM read each)
    us = time_us(lambda: ops.power_pass_chunk(x, b, q, qb, interpret=True))
    rows.append(("kernel_power_pass_chunk", us, "dYa+dYb fused, P stays in VMEM"))


def engine_comparison(out_path: str = "results/kernel_bench.json",
                      rows: list | None = None) -> dict:
    """Time the per-chunk data-pass updates under both engines and write
    a BENCH json.  On CPU the kernel engine runs in interpret mode, so
    the jnp column wins on wall clock — the json's purpose is tracking
    both engines' timings per backend plus the max engine disagreement."""
    key = jax.random.PRNGKey(0)
    n, da, db, kt = 1024, 512, 384, 256
    a = jax.random.normal(key, (n, da), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (n, db), jnp.float32)
    qa = jax.random.normal(jax.random.PRNGKey(2), (da, kt), jnp.float32)
    qb = jax.random.normal(jax.random.PRNGKey(3), (db, kt), jnp.float32)

    power_jnp = jax.jit(ref.power_pass_ref)
    final_jnp = jax.jit(ref.final_pass_ref)
    cases = [
        ("power_pass_chunk", lambda: ops.power_pass_chunk(a, b, qa, qb),
         lambda: power_jnp(a, b, qa, qb)),
        ("final_pass_chunk", lambda: ops.final_pass_chunk(a, b, qa, qb),
         lambda: final_jnp(a, b, qa, qb)),
    ]
    results = []
    for name, run_k, run_j in cases:
        out_k = jax.tree.leaves(run_k())
        out_j = jax.tree.leaves(run_j())
        err = max(
            float(jnp.linalg.norm(gk - gj) / jnp.maximum(jnp.linalg.norm(gj), 1e-30))
            for gk, gj in zip(out_k, out_j)
        )
        us_k = time_us(run_k)
        us_j = time_us(run_j)
        results.append({"name": name, "shape": [n, da, db, kt],
                        "kernels_us": round(us_k, 1), "jnp_us": round(us_j, 1),
                        "max_rel_err": err})
        if rows is not None:
            rows.append((f"engine_{name}_kernels", us_k, f"rel_err_vs_jnp={err:.2e}"))
            rows.append((f"engine_{name}_jnp", us_j, "oracle path"))

    bench = {
        "bench": "cca_data_pass_engines",
        "backend": jax.default_backend(),
        "interpret": jax.default_backend() != "tpu",
        "results": results,
    }
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2)
    print("BENCH " + json.dumps(bench))
    return bench


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="results/kernel_bench.json")
    args = ap.parse_args(argv)
    rows: list = []
    kernel_benchmarks(rows)
    engine_comparison(args.out, rows)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
