"""IO-overlap benchmark: the out-of-core data pass with and without
async prefetch.

Builds (once, cached under ``--workdir``) an on-disk view store from a
planted corpus, then runs Algorithm 1's q+1 data passes from disk via
``repro.store.PassRunner`` at prefetch depth 0 (synchronous reads — the
paper's naive out-of-core loop) and depth 2 (double-buffered shard read
+ ``jax.device_put`` overlapped with the per-chunk update), reporting
rows/s and the measured IO stall for each:

    PYTHONPATH=src python -m benchmarks.io_bench --out results/BENCH_io.json

Emits a BENCH json (and is part of ``make bench``) so the per-PR perf
trajectory records the overlap win.

IO model: the primary comparison throttles chunk reads to
``--io-gbps`` (default 0.1 GB/s — a contended distributed-FS /
networked-disk read, the paper's actual out-of-core setting).  The
throttle is a
GIL-free wait, so it overlaps with compute exactly the way a blocking
DFS read does.  Unthrottled local reads are also measured and reported
under ``local_page_cache`` for the record, but on a small host they
are pure memcpy out of the page cache: they need a CPU, not a device,
so there is nothing for the pipeline to hide (on a 2-core container
the best case is parity minus thread overhead).

The engine defaults to the pure-jnp oracle path off-TPU: this benchmark
measures the IO pipeline, and interpret-mode Pallas would bury the IO
signal under kernel emulation overhead.  On a TPU backend the fused
kernels are the thing being overlapped — use ``--engine kernels``.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax

from benchmarks.common import write_bench
from repro.core.rcca import RCCAConfig
from repro.data import PlantedCCAData
from repro.store import PassRunner, ViewStoreReader, ingest_planted
from repro.store.format import MANIFEST


class ThrottledReader(ViewStoreReader):
    """Reader that models a bandwidth-limited filesystem: every chunk
    read is padded to ``bytes / gbps`` wall time with a GIL-releasing
    sleep, like a blocking remote read."""

    def __init__(self, path: str, gbps: float, **kw):
        super().__init__(path, **kw)
        self.gbps = gbps

    def get_chunk(self, idx):
        t0 = time.perf_counter()
        a, b = super().get_chunk(idx)
        budget = (a.nbytes + b.nbytes) / (self.gbps * 1e9)
        short = budget - (time.perf_counter() - t0)
        if short > 0:
            time.sleep(short)
        return a, b


def _ensure_store(workdir: str, *, n: int, d: int, chunk: int) -> str:
    path = os.path.join(workdir, f"io_bench_store_n{n}_d{d}_c{chunk}")
    if not os.path.exists(os.path.join(path, MANIFEST)):
        data = PlantedCCAData(n=n, da=d, db=d, rank=32, seed=7, chunk=chunk)
        ingest_planted(path, data, rows_per_shard=chunk)
    return path


def _best_pass(path: str, cfg, key, *, engine: str, depth: int,
               gbps: float, repeat: int) -> dict:
    """Best-of-``repeat`` run of all passes at one prefetch depth."""
    best = None
    for _ in range(repeat):
        reader = (ThrottledReader(path, gbps, mmap=False) if gbps > 0
                  else ViewStoreReader(path, mmap=False))
        # sync_chunks=1: strict bounded pipeline — each chunk's update
        # completes before the next is consumed, so the comparison
        # isolates the prefetcher (async dispatch can't queue ahead)
        io = PassRunner(reader, cfg, engine=engine, prefetch=depth,
                        sync_chunks=1).fit(key).diagnostics["io"]
        if best is None or io["rows_per_s"] > best["rows_per_s"]:
            best = io
    return best


def io_overlap(out_path: str = "results/BENCH_io.json", rows: list | None = None,
               *, n: int = 16384, d: int = 512, chunk: int = 2048,
               k: int = 32, p: int = 224, q: int = 1, engine: str | None = None,
               io_gbps: float = 0.1, repeat: int = 3,
               workdir: str = "/tmp/repro_io_bench") -> dict:
    if engine is None:
        # see module docstring: IO pipeline signal, not kernel emulation
        engine = "kernels" if jax.default_backend() == "tpu" else "jnp"
    os.makedirs(workdir, exist_ok=True)
    path = _ensure_store(workdir, n=n, d=d, chunk=chunk)
    reader = ViewStoreReader(path)
    cfg = RCCAConfig(k=k, p=p, q=q, nu=0.01)
    key = jax.random.PRNGKey(0)

    results = []
    best = {}
    for depth in (0, 2):
        io = _best_pass(path, cfg, key, engine=engine, depth=depth,
                        gbps=io_gbps, repeat=repeat)
        best[depth] = io
        results.append({
            "name": f"data_pass_prefetch_{depth}",
            "prefetch_depth": depth,
            "rows_per_s": io["rows_per_s"],
            "wall_s": io["wall_s"],
            "read_s": io["read_s"],
            "io_stall_s": io["io_stall_s"],
            "rows": io["rows"],
            "bytes": io["bytes"],
        })
        if rows is not None:
            rows.append((f"io_pass_prefetch{depth}", io["wall_s"] * 1e6,
                         f"rows/s={io['rows_per_s']:.0f} stall_s={io['io_stall_s']}"))

    # unthrottled local reads, for the record (see module docstring)
    local = {
        depth: _best_pass(path, cfg, key, engine=engine, depth=depth,
                          gbps=0.0, repeat=repeat)
        for depth in (0, 2)
    }

    speedup = best[2]["rows_per_s"] / max(best[0]["rows_per_s"], 1e-9)
    bench = {
        "bench": "cca_io_overlap",
        "backend": jax.default_backend(),
        "engine": engine,
        "io_model": {"gbps": io_gbps, "kind": "throttled DFS-like reads"},
        "shape": {"n": n, "da": d, "db": d, "chunk": chunk,
                  "k": k, "p": p, "q": q,
                  "store_bytes": reader.nbytes, "n_chunks": reader.n_chunks},
        "results": results,
        "prefetch_speedup": round(speedup, 4),
        "stall_hidden_s": round(best[0]["io_stall_s"] - best[2]["io_stall_s"], 4),
        "local_page_cache": {
            f"prefetch_{depth}": {"rows_per_s": io["rows_per_s"],
                                  "wall_s": io["wall_s"],
                                  "io_stall_s": io["io_stall_s"]}
            for depth, io in local.items()
        },
    }
    bench = write_bench(bench, out_path)
    return bench


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="results/BENCH_io.json")
    ap.add_argument("--n", type=int, default=16384)
    ap.add_argument("--d", type=int, default=512)
    ap.add_argument("--chunk", type=int, default=2048)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--p", type=int, default=224)
    ap.add_argument("--q", type=int, default=1)
    ap.add_argument("--engine", default=None, choices=["kernels", "jnp"])
    ap.add_argument("--io-gbps", type=float, default=0.1,
                    help="modelled filesystem read bandwidth for the "
                         "primary comparison (0 = unthrottled local)")
    ap.add_argument("--repeat", type=int, default=3)
    ap.add_argument("--workdir", default="/tmp/repro_io_bench")
    args = ap.parse_args(argv)
    io_overlap(args.out, n=args.n, d=args.d, chunk=args.chunk, k=args.k,
               p=args.p, q=args.q, engine=args.engine, io_gbps=args.io_gbps,
               repeat=args.repeat, workdir=args.workdir)


if __name__ == "__main__":
    main()
