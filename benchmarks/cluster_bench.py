"""Cluster scaling benchmark: the two-pass fit across worker processes.

Runs the ``repro.cluster`` coordinator over an on-disk view store at
worker counts {1, 2, 4} and records rows/s, per-pass barrier wall time
and the merge-tree overhead (time spent loading + tree-reducing the
per-group partials, which is the coordinator's only serial section):

    PYTHONPATH=src python -m benchmarks.cluster_bench --out results/BENCH_cluster.json

Reading the numbers: on this repo's 2-core CI container the workers
time-share 2 CPUs with interpret-mode-free jnp compute, so rows/s does
NOT scale with worker count — the measurement records the
coordination overhead floor (process spawn + jax import ≈ seconds per
worker, barrier polling, merge tree) that a real deployment amortizes
over corpus size.  On a multi-host cluster each worker owns real
cores/devices and the same code path scales; what this benchmark
guards is that the overhead stays flat per worker and the merge stays
milliseconds-scale.  A single-process ``PassRunner`` fit over the same
store is included as the no-cluster baseline.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax

from benchmarks.common import write_bench
from repro.core.rcca import RCCAConfig
from repro.data import PlantedCCAData
from repro.store import PassRunner, ViewStoreReader, ingest_planted
from repro.store.format import MANIFEST


def _ensure_store(workdir: str, *, n: int, d: int, chunk: int) -> str:
    path = os.path.join(workdir, f"cluster_bench_store_n{n}_d{d}_c{chunk}")
    if not os.path.exists(os.path.join(path, MANIFEST)):
        data = PlantedCCAData(n=n, da=d, db=d, rank=32, seed=7, chunk=chunk)
        ingest_planted(path, data, rows_per_shard=chunk)
    return path


def cluster_scaling(out_path: str = "results/BENCH_cluster.json",
                    rows: list | None = None, *, n: int = 16384, d: int = 256,
                    chunk: int = 1024, k: int = 32, p: int = 96, q: int = 1,
                    engine: str | None = None, merge_group: int = 4,
                    workers: tuple = (1, 2, 4),
                    workdir: str = "/tmp/repro_cluster_bench") -> dict:
    from repro.cluster import ClusterCoordinator

    if engine is None:
        # interpret-mode Pallas would bury the coordination signal
        # under kernel emulation overhead (same rationale as io_bench)
        engine = "kernels" if jax.default_backend() == "tpu" else "jnp"
    os.makedirs(workdir, exist_ok=True)
    path = _ensure_store(workdir, n=n, d=d, chunk=chunk)
    reader = ViewStoreReader(path)
    cfg = RCCAConfig(k=k, p=p, q=q, nu=0.01)
    key = jax.random.PRNGKey(0)

    t0 = time.perf_counter()
    PassRunner(reader, cfg, engine=engine, prefetch=2,
               merge_group=merge_group).fit(key)
    base_wall = time.perf_counter() - t0
    total_rows = reader.n * (q + 1)

    results = [{
        "name": "single_process_passrunner",
        "workers": 0,
        "wall_s": round(base_wall, 4),
        "rows_per_s": round(total_rows / base_wall, 2),
    }]
    if rows is not None:
        rows.append(("cluster_1proc_baseline", base_wall * 1e6,
                     f"rows/s={total_rows / base_wall:.0f}"))

    for w in workers:
        co = ClusterCoordinator(reader, cfg, os.path.join(workdir, f"cl_{w}"),
                                n_workers=w, engine=engine,
                                merge_group=merge_group)
        t0 = time.perf_counter()
        res = co.fit(key)
        wall = time.perf_counter() - t0
        passes = res.diagnostics["cluster"]["passes"]
        merge_s = sum(pp["merge_s"] for pp in passes)
        results.append({
            "name": f"cluster_{w}_workers",
            "workers": w,
            "wall_s": round(wall, 4),
            "rows_per_s": round(total_rows / wall, 2),
            "merge_tree_s": round(merge_s, 4),
            "merge_tree_frac": round(merge_s / wall, 4),
            "workers_spawned": sum(pp["workers_spawned"] for pp in passes),
            "per_pass": passes,
        })
        if rows is not None:
            rows.append((f"cluster_{w}_workers", wall * 1e6,
                         f"rows/s={total_rows / wall:.0f} merge_s={merge_s:.3f}"))

    bench = {
        "bench": "cca_cluster_scaling",
        "backend": jax.default_backend(),
        "engine": engine,
        "host": {"cpus": os.cpu_count()},
        "shape": {"n": n, "da": d, "db": d, "chunk": chunk, "k": k, "p": p,
                  "q": q, "merge_group": merge_group,
                  "n_chunks": reader.n_chunks,
                  "n_groups": -(-reader.n_chunks // merge_group)},
        "results": results,
        "note": ("2-core container: workers time-share the host, so "
                 "rows/s records coordination overhead, not scaling — "
                 "see module docstring"),
    }
    bench = write_bench(bench, out_path)
    return bench


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="results/BENCH_cluster.json")
    ap.add_argument("--n", type=int, default=16384)
    ap.add_argument("--d", type=int, default=256)
    ap.add_argument("--chunk", type=int, default=1024)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--p", type=int, default=96)
    ap.add_argument("--q", type=int, default=1)
    ap.add_argument("--merge-group", type=int, default=4)
    ap.add_argument("--engine", default=None, choices=["kernels", "jnp"])
    ap.add_argument("--workers", default="1,2,4")
    ap.add_argument("--workdir", default="/tmp/repro_cluster_bench")
    args = ap.parse_args(argv)
    cluster_scaling(args.out, n=args.n, d=args.d, chunk=args.chunk, k=args.k,
                    p=args.p, q=args.q, engine=args.engine,
                    merge_group=args.merge_group,
                    workers=tuple(int(w) for w in args.workers.split(",")),
                    workdir=args.workdir)


if __name__ == "__main__":
    main()
