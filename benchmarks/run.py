"""Benchmark harness — one function per paper table/figure plus kernel
micro-benchmarks and the roofline table derived from the dry-run.

    PYTHONPATH=src python -m benchmarks.run [--only fig1,fig2a,...]
    PYTHONPATH=src python -m benchmarks.run --artifacts

Prints ``name,us_per_call,derived`` CSV.  ``--artifacts`` is the single
entry point for the committed perf record: it runs every
BENCH-producing suite, writes each artifact through
:func:`benchmarks.common.write_bench` (schema + commit/backend metadata
stamp), and folds them all into ``results/TRAJECTORY.json``.
"""

from __future__ import annotations

import argparse


def artifacts(results_dir: str = "results") -> None:
    """All committed BENCH artifacts + the trajectory, in one pass."""
    import os

    from repro.obs import trajectory

    from . import cluster_bench, io_bench, kernel_bench

    kernel_bench.engine_comparison(
        os.path.join(results_dir, "kernel_bench.json"))
    kernel_bench.bucketed_report(
        os.path.join(results_dir, "BENCH_bucketed.json"))
    kernel_bench.seeded_report(
        os.path.join(results_dir, "BENCH_seeded.json"))
    kernel_bench.staged_report(
        os.path.join(results_dir, "BENCH_staged.json"))
    io_bench.io_overlap(os.path.join(results_dir, "BENCH_io.json"))
    cluster_bench.cluster_scaling(
        os.path.join(results_dir, "BENCH_cluster.json"))
    out = trajectory.write(results_dir)
    print(f"TRAJECTORY: wrote {out}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig1,fig2a,table2b,fig3,"
                         "kernels,staged,io,cluster,roofline")
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--artifacts", action="store_true",
                    help="write every BENCH json + results/TRAJECTORY.json")
    ap.add_argument("--results", default="results",
                    help="artifact output directory (with --artifacts)")
    args = ap.parse_args(argv)

    if args.artifacts:
        artifacts(args.results)
        return

    from . import cluster_bench, io_bench, kernel_bench, paper_figures, roofline

    suites = {
        "fig1": paper_figures.fig1_spectrum,
        "fig2a": paper_figures.fig2a_pq_sweep,
        "table2b": paper_figures.table2b_timings,
        "fig3": paper_figures.fig3_nu_sweep,
        "kernels": kernel_bench.kernel_benchmarks,
        "staged": lambda rows: kernel_bench.staged_report(rows=rows),
        "io": lambda rows: io_bench.io_overlap(rows=rows),
        "cluster": lambda rows: cluster_bench.cluster_scaling(rows=rows),
        "roofline": lambda rows: roofline.roofline_rows(rows, args.dryrun_dir),
    }
    wanted = list(suites) if args.only is None else args.only.split(",")

    rows = []
    for name in wanted:
        suites[name](rows)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
