"""Benchmark harness — one function per paper table/figure plus kernel
micro-benchmarks and the roofline table derived from the dry-run.

    PYTHONPATH=src python -m benchmarks.run [--only fig1,fig2a,...]

Prints ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import argparse
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig1,fig2a,table2b,fig3,"
                         "kernels,io,cluster,roofline")
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    args = ap.parse_args(argv)

    from . import cluster_bench, io_bench, kernel_bench, paper_figures, roofline

    suites = {
        "fig1": paper_figures.fig1_spectrum,
        "fig2a": paper_figures.fig2a_pq_sweep,
        "table2b": paper_figures.table2b_timings,
        "fig3": paper_figures.fig3_nu_sweep,
        "kernels": kernel_bench.kernel_benchmarks,
        "io": lambda rows: io_bench.io_overlap(rows=rows),
        "cluster": lambda rows: cluster_bench.cluster_scaling(rows=rows),
        "roofline": lambda rows: roofline.roofline_rows(rows, args.dryrun_dir),
    }
    wanted = list(suites) if args.only is None else args.only.split(",")

    rows = []
    for name in wanted:
        suites[name](rows)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
