"""One benchmark per paper table/figure, at CPU scale with a planted
corpus whose exact optimum is computable.

fig1   — spectrum of (1/n)AᵀB via two-pass randomized SVD
fig2a  — objective vs (q, p), vs the Horst '120-pass' reference
table2b— timings + train/test objectives: rcca / Horst / Horst+rcca
fig3   — ν sensitivity of train & test objective, rcca vs Horst
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    HorstConfig,
    cca_objective,
    exact_cca,
    horst_cca,
    randomized_cca,
)
from repro.core.linalg import orth, topk_svd
from repro.core.rcca import RCCAConfig

from .common import europarl_standin

K = 12


def fig1_spectrum(rows):
    """Top-k spectrum of (1/n)AᵀB estimated by two-pass randomized SVD,
    vs the exact spectrum (checkable because the corpus is planted)."""
    A, B, _, _ = europarl_standin()
    n = A.shape[0]
    kt = 48
    key = jax.random.PRNGKey(0)
    t0 = time.perf_counter()
    Q = jax.random.normal(key, (B.shape[1], kt))
    Y = A.T @ (B @ Q)  # pass 1
    Q = orth(Y)
    Z = B.T @ (A @ Q)  # pass 2
    _, S, _ = topk_svd(Z.T / n, kt)
    us = (time.perf_counter() - t0) * 1e6
    S_exact = jnp.linalg.svd(A.T @ B / n, compute_uv=False)[:kt]
    err = float(jnp.max(jnp.abs(S - S_exact) / S_exact[0]))
    rows.append(("fig1_spectrum_2pass_rsvd", us, f"rel_spectrum_err={err:.2e}"))
    decay = float(S_exact[0] / S_exact[min(20, kt - 1)])
    rows.append(("fig1_spectrum_decay_s0_over_s20", 0.0, f"{decay:.1f}x"))


def fig2a_pq_sweep(rows):
    A, B, At, Bt = europarl_standin()
    lam = 1e-3
    ex = exact_cca(A, B, K, lam, lam)
    opt = float(jnp.sum(ex.rho))
    rows.append(("fig2a_exact_optimum", 0.0, f"obj={opt:.4f}"))
    h = horst_cca(A, B, HorstConfig(k=K, iters=60, lam_a=lam, lam_b=lam),
                  key=jax.random.PRNGKey(7))
    rows.append(("fig2a_horst_60it", 0.0,
                 f"obj={float(jnp.sum(h.rho)):.4f}"))
    for q in [0, 1, 2, 3]:
        for p in [8, 24, 64]:
            cfg = RCCAConfig(k=K, p=p, q=q, lam_a=lam, lam_b=lam)
            t0 = time.perf_counter()
            r = randomized_cca(A, B, cfg, jax.random.PRNGKey(1))
            jax.block_until_ready(r.rho)
            us = (time.perf_counter() - t0) * 1e6
            obj = float(jnp.sum(r.rho))
            rows.append((f"fig2a_rcca_q{q}_p{p}", us,
                         f"obj={obj:.4f} frac_of_opt={obj/opt:.4f}"))


def table2b_timings(rows):
    A, B, At, Bt = europarl_standin()
    nu = 0.01
    lam_a = nu * float(jnp.sum(A**2)) / A.shape[1]
    lam_b = nu * float(jnp.sum(B**2)) / B.shape[1]
    ex = exact_cca(A, B, K, lam_a, lam_b)
    target = 0.999 * float(jnp.sum(ex.rho))

    def passes_to_target(hist, per_iter_passes=2, offset=0):
        idx = np.nonzero(np.asarray(hist) >= target)[0]
        return (int(idx[0]) + 1) * per_iter_passes + offset if len(idx) else -1

    # RandomizedCCA rows (q, p) — train/test objectives + time
    for q, p in [(0, 24), (0, 64), (1, 24), (1, 64), (2, 64)]:
        cfg = RCCAConfig(k=K, p=p, q=q, nu=nu)
        t0 = time.perf_counter()
        r = randomized_cca(A, B, cfg, jax.random.PRNGKey(3))
        jax.block_until_ready(r.rho)
        us = (time.perf_counter() - t0) * 1e6
        tr = float(cca_objective(A, B, r.Xa, r.Xb))
        te = float(cca_objective(At, Bt, r.Xa, r.Xb))
        rows.append((f"table2b_rcca_q{q}_p{p}", us,
                     f"train={tr:.4f} test={te:.4f} passes={q + 1}"))

    # Horst cold
    t0 = time.perf_counter()
    h = horst_cca(A, B, HorstConfig(k=K, iters=60, nu=nu), key=jax.random.PRNGKey(4))
    jax.block_until_ready(h.rho)
    us = (time.perf_counter() - t0) * 1e6
    tr = float(cca_objective(A, B, h.Xa, h.Xb))
    te = float(cca_objective(At, Bt, h.Xa, h.Xb))
    rows.append(("table2b_horst_cold", us,
                 f"train={tr:.4f} test={te:.4f} "
                 f"passes_to_99.9pct={passes_to_target(h.objective_history)}"))

    # Horst + rcca warm start (paper: 120 → 34 passes)
    t0 = time.perf_counter()
    r = randomized_cca(A, B, RCCAConfig(k=K, p=64, q=1, nu=nu), jax.random.PRNGKey(5))
    h2 = horst_cca(A, B, HorstConfig(k=K, iters=60, nu=nu), init_Xb=r.Xb)
    jax.block_until_ready(h2.rho)
    us = (time.perf_counter() - t0) * 1e6
    tr = float(cca_objective(A, B, h2.Xa, h2.Xb))
    te = float(cca_objective(At, Bt, h2.Xa, h2.Xb))
    rows.append(("table2b_horst_plus_rcca", us,
                 f"train={tr:.4f} test={te:.4f} "
                 f"passes_to_99.9pct={passes_to_target(h2.objective_history, offset=2)}"))


def fig3_nu_sweep(rows):
    A, B, At, Bt = europarl_standin()
    for nu in [1e-4, 1e-3, 1e-2, 1e-1]:
        r = randomized_cca(A, B, RCCAConfig(k=K, p=64, q=2, nu=nu), jax.random.PRNGKey(6))
        h = horst_cca(A, B, HorstConfig(k=K, iters=60, nu=nu), key=jax.random.PRNGKey(7))
        tr_r = float(cca_objective(A, B, r.Xa, r.Xb))
        te_r = float(cca_objective(At, Bt, r.Xa, r.Xb))
        tr_h = float(cca_objective(A, B, h.Xa, h.Xb))
        te_h = float(cca_objective(At, Bt, h.Xa, h.Xb))
        rows.append((f"fig3_nu{nu:g}", 0.0,
                     f"rcca_train={tr_r:.4f} rcca_test={te_r:.4f} "
                     f"horst_train={tr_h:.4f} horst_test={te_h:.4f}"))
