"""End-to-end driver: the paper's Europarl experiment, faithfully staged.

Pipeline (paper §4):
  1. paired "sentences" → bag-of-words → feature hashing into d slots
     per view (Weinberger et al. hashing, the paper uses 2^19 slots);
  2. RandomizedCCA (Algorithm 1) over the hashed views, streaming the
     corpus in row chunks (out-of-core semantics, q+1 data passes);
  3. report Σρ train/test, feasibility, and the Horst+rcca warm-start
     comparison (paper Table 2b).

Scaled to CPU: n=20k synthetic paired docs, 2^12 hash slots.  Flags let
you push n/d up on bigger hosts; the same code path is what
launch/cca_fit.py runs distributed.

With ``--store DIR`` the hashed views are ingested once into an
on-disk view store (repro.store) and the fit streams from disk through
the async-prefetching PassRunner — the paper's out-of-core setting:
featurize once, then any number of experiments re-read the store
instead of re-hashing.

    PYTHONPATH=src python examples/europarl_cca.py [--store /tmp/europarl]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import HorstConfig, cca_objective, horst_cca
from repro.core.rcca import RCCAConfig, randomized_cca_iterator
from repro.data import HashingFeaturizer


def synth_paired_docs(n, vocab=50_000, doc_len=30, seed=0):
    """Paired 'translations': view B's tokens are a deterministic map of
    view A's plus noise — so the views share latent structure exactly
    like sentence-aligned Europarl."""
    rng = np.random.default_rng(seed)
    # zipfian-ish token draws
    base = rng.zipf(1.3, size=(n, doc_len)).clip(1, vocab - 1)
    translate = lambda t: (t * 2_654_435_761) % vocab + 1  # fixed "dictionary"
    noise_mask = rng.random((n, doc_len)) < 0.2
    other = rng.zipf(1.3, size=(n, doc_len)).clip(1, vocab - 1)
    paired = np.where(noise_mask, other, translate(base))
    return base.astype(np.int64), paired.astype(np.int64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--slots", type=int, default=4096)  # paper: 2**19
    ap.add_argument("--k", type=int, default=16)        # paper: 60
    ap.add_argument("--p", type=int, default=64)        # paper: 910/2000
    ap.add_argument("--q", type=int, default=1)
    ap.add_argument("--chunk", type=int, default=2048)
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="ingest the hashed train views into an on-disk "
                         "view store and fit from it (out-of-core path "
                         "with async prefetch)")
    args = ap.parse_args()

    print(f"[1/3] hashing {args.n} paired docs into 2×{args.slots} slots...")
    docs_a, docs_b = synth_paired_docs(args.n)
    ha = HashingFeaturizer(args.slots, seed=1)
    hb = HashingFeaturizer(args.slots, seed=2)
    n_tr = int(args.n * 0.9)

    def chunks(lo, hi):
        for s in range(lo, hi, args.chunk):
            e = min(s + args.chunk, hi)
            yield (jnp.asarray(ha.featurize_batch(docs_a[s:e])),
                   jnp.asarray(hb.featurize_batch(docs_b[s:e])))

    print(f"[2/3] RandomizedCCA k={args.k} p={args.p} q={args.q} "
          f"({args.q + 1} data passes, streamed)...")
    cfg = RCCAConfig(k=args.k, p=args.p, q=args.q, nu=0.01, center=True)
    t0 = time.time()
    if args.store:
        import os

        from repro.store import PassRunner, ViewStoreReader, ingest_chunks
        from repro.store.format import MANIFEST

        if not os.path.exists(os.path.join(args.store, MANIFEST)):
            reader = ingest_chunks(args.store, chunks(0, n_tr), chunk=args.chunk)
            print(f"      ingested {reader.n} hashed rows "
                  f"({reader.nbytes / 1e6:.1f} MB) → {args.store}")
        else:
            reader = ViewStoreReader(args.store)
            if (reader.n, reader.da, reader.db) != (n_tr, args.slots, args.slots):
                raise SystemExit(
                    f"view store {args.store} holds n={reader.n} "
                    f"da={reader.da} db={reader.db} but the flags ask for "
                    f"n={n_tr} slots={args.slots} — point --store at a "
                    "fresh directory (or delete it) to re-ingest")
            print(f"      reusing view store {args.store} (n={reader.n})")
        res = PassRunner(reader, cfg).fit(jax.random.PRNGKey(0))
        print(f"      io: {res.diagnostics['io']}")
    else:
        res = randomized_cca_iterator(
            lambda: chunks(0, n_tr), args.slots, args.slots, cfg, jax.random.PRNGKey(0)
        )
    print(f"      done in {time.time()-t0:.1f}s; sum rho = {float(jnp.sum(res.rho)):.4f}")

    # evaluate train/test objective on materialized matrices (small scale)
    A_tr = jnp.concatenate([a for a, _ in chunks(0, n_tr)])
    B_tr = jnp.concatenate([b for _, b in chunks(0, n_tr)])
    A_te = jnp.concatenate([a for a, _ in chunks(n_tr, args.n)])
    B_te = jnp.concatenate([b for _, b in chunks(n_tr, args.n)])
    mu_a, mu_b = jnp.mean(A_tr, 0), jnp.mean(B_tr, 0)
    tr = float(cca_objective(A_tr - mu_a, B_tr - mu_b, res.Xa, res.Xb))
    te = float(cca_objective(A_te - mu_a, B_te - mu_b, res.Xa, res.Xb))
    print(f"      objective: train {tr:.4f} / test {te:.4f}")

    print("[3/3] Horst+rcca warm start (paper Table 2b)...")
    t0 = time.time()
    h = horst_cca(A_tr - mu_a, B_tr - mu_b,
                  HorstConfig(k=args.k, iters=10, nu=0.01), init_Xb=res.Xb)
    tr_h = float(cca_objective(A_tr - mu_a, B_tr - mu_b, h.Xa, h.Xb))
    print(f"      10 Horst iterations from rcca init: train {tr_h:.4f} "
          f"(+{tr_h - tr:.4f}) in {time.time()-t0:.1f}s")
    print("OK")


if __name__ == "__main__":
    main()
