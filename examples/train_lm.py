"""End-to-end LM training: a ~100M-param gemma3-family model for a few
hundred steps with checkpointing — exercising the same train_step the
512-chip dry-run lowers, on the host mesh.

    PYTHONPATH=src python examples/train_lm.py --steps 300

(defaults to a quick 20-step run; pass --steps 300 for the full demo)
"""

import argparse
import dataclasses

from repro.configs.gemma3_1b import config as gemma3_full
from repro.launch.train import main as train_main
from repro.models.config import AttnConfig, FFNConfig


def hundred_m_config():
    """gemma3-family, ~100M params (same pattern, scaled width/depth)."""
    base = gemma3_full()
    return dataclasses.replace(
        base,
        name="gemma3-100m",
        d_model=512,
        n_layers=12,
        vocab=32_768,
        attn=AttnConfig(n_heads=8, n_kv=2, head_dim=64,
                        rope_theta=1_000_000.0, local_rope_theta=10_000.0,
                        window=256, qk_norm=True),
        ffn=FFNConfig(d_ff=2048, act="gelu", gated=True),
        layer_pattern=tuple(
            ["local", "local", "local", "local", "local", "attn"] * 2
        ),
        max_seq=2048,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # register the 100M config under a temporary name
    import repro.configs as C

    cfg = hundred_m_config()

    class _Mod:
        @staticmethod
        def config():
            return cfg

        @staticmethod
        def smoke_config():
            return cfg

    C.CANONICAL["gemma3-100m"] = "gemma3-100m"
    import sys
    sys.modules["repro.configs.gemma3_100m"] = _Mod  # type: ignore

    train_main([
        "--arch", "gemma3-100m", "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--loss-chunks", "4",
    ])


if __name__ == "__main__":
    main()
