"""The paper's technique × the model zoo: align the representations of
two different LMs over paired text with distributed RandomizedCCA.

This is the modern analogue of the paper's multilingual-embedding
application: view A = model 1's hidden states, view B = model 2's
hidden states of the same token stream; CCA finds the shared subspace.
Also demonstrates SVCCA-style layer analysis within one model.

    PYTHONPATH=src python examples/activation_cca.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import randomized_cca
from repro.core.harvest import activation_views, paired_activation_stream
from repro.core.rcca import RCCAConfig, randomized_cca_iterator
from repro.data import SyntheticTokenStream
from repro.models import build_model


def main():
    cfg1 = get_config("granite-3-2b", smoke=True)
    cfg2 = get_config("gemma3-1b", smoke=True)  # different family!
    # same vocab so both can read the same stream
    import dataclasses
    cfg2 = dataclasses.replace(cfg2, vocab=cfg1.vocab)

    m1, m2 = build_model(cfg1), build_model(cfg2)
    p1 = m1.init(jax.random.PRNGKey(0))
    p2 = m2.init(jax.random.PRNGKey(1))

    stream = SyntheticTokenStream(vocab=cfg1.vocab, batch=8, seq=32, seed=3)
    batches = [{"tokens": jnp.asarray(stream.get_batch(i)[:, :-1])} for i in range(8)]

    print("[1/2] streaming activation harvest → RandomizedCCA "
          f"(views: {cfg1.name} vs {cfg2.name})")
    da = cfg1.d_model
    db = cfg2.d_model
    cfg = RCCAConfig(k=8, p=24, q=1, nu=0.01, center=True)
    res = randomized_cca_iterator(
        lambda: paired_activation_stream(m1, p1, m2, p2, iter(batches)),
        da, db, cfg, jax.random.PRNGKey(4),
    )
    rho = [f"{r:.3f}" for r in res.rho]
    print(f"      cross-model canonical correlations: {rho}")

    # negative control: break the row ALIGNMENT (CCA finds aligned
    # structure; shuffling one view's rows destroys it — token AND
    # positional correlation both vanish)
    def shuffled_pairs():
        for i, b in enumerate(batches):
            va = activation_views(m1, p1, b)
            vb = activation_views(m2, p2, b)
            perm = jax.random.permutation(jax.random.PRNGKey(40 + i), vb.shape[0])
            yield va, vb[perm]

    res0 = randomized_cca_iterator(
        shuffled_pairs, da, db, cfg, jax.random.PRNGKey(4)
    )
    print(f"      shuffled-alignment control:          "
          f"{[f'{r:.3f}' for r in res0.rho]}")
    gap = float(jnp.sum(res.rho) - jnp.sum(res0.rho))
    print(f"      aligned-vs-shuffled gap: {gap:.3f} (should be >> 0)")
    assert gap > 0.5

    print("[2/2] SVCCA-style: same model, half depth vs full depth")
    A = activation_views(m1, p1, batches[0])
    from repro.core.harvest import layer_views
    try:
        Ahalf = layer_views(m1, p1, batches[0], 0.5)
        r = randomized_cca(Ahalf, A, RCCAConfig(k=8, p=16, q=1, nu=0.01),
                           jax.random.PRNGKey(5))
        print(f"      depth-0.5 vs depth-1.0 correlations: "
              f"{[f'{x:.3f}' for x in r.rho]}")
    except NotImplementedError:
        print("      (layer_views supports attn family only)")
    print("OK")


if __name__ == "__main__":
    main()
