"""Quickstart: RandomizedCCA on a planted two-view corpus, validated
against the exact dense oracle.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import exact_cca, feasibility_errors, randomized_cca
from repro.core.rcca import RCCAConfig
from repro.data import planted_views


def main():
    # two views with a shared 8-dim latent
    A, B = planted_views(0, n=4000, da=64, db=48, rank=8, noise=0.4)
    A, B = jnp.asarray(A), jnp.asarray(B)

    cfg = RCCAConfig(k=6, p=32, q=1, nu=0.01)
    result = randomized_cca(A, B, cfg, jax.random.PRNGKey(0))

    print("canonical correlations:", [f"{r:.4f}" for r in result.rho])

    lam_a = float(result.diagnostics["lam_a"])
    lam_b = float(result.diagnostics["lam_b"])
    exact = exact_cca(A, B, cfg.k, lam_a, lam_b)
    print("exact oracle:          ", [f"{r:.4f}" for r in exact.rho])

    errs = feasibility_errors(A, B, result.Xa, result.Xb, lam_a, lam_b)
    print("feasibility residuals: ", {k: f"{float(v):.2e}" for k, v in errs.items()})

    gap = float(jnp.sum(exact.rho) - jnp.sum(result.rho))
    print(f"objective gap vs exact: {gap:.5f}")
    assert gap < 0.05, "RandomizedCCA should be near-exact at this scale"
    print("OK")


if __name__ == "__main__":
    main()
