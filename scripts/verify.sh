#!/usr/bin/env bash
# Tier-1 verification entry point (also: `make verify`).
#
#   scripts/verify.sh          # full tier-1 suite + kernel-parity subset
#   scripts/verify.sh --quick  # only the interpret-mode kernel-parity subset
#
# Extra args after the mode flag are forwarded to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

quick=0
if [[ "${1:-}" == "--quick" ]]; then
  quick=1
  shift
fi

# interpret-mode kernel parity: every Pallas kernel against its jnp
# oracle, the engine-parity sweep of the data-pass drivers, and the
# column-bucketed fused-kernel parity/regression suite
parity() {
  python -m pytest -q tests/test_kernels.py tests/test_engine_parity.py \
    tests/test_bucketed_kernels.py tests/test_bucketed_properties.py "$@"
}

if [[ "$quick" == 1 ]]; then
  parity "$@"
else
  python -m pytest -x -q "$@"
  parity
fi
