#!/usr/bin/env bash
# Tier-1 verification entry point (also: `make verify`).
#
#   scripts/verify.sh            # full tier-1 suite + kernel-parity subset
#   scripts/verify.sh --quick    # only the interpret-mode kernel-parity subset
#   scripts/verify.sh --cluster  # only the multi-worker cluster + store suites
#   scripts/verify.sh --topology # exec topology-parity + hybrid suites under
#                                # a forced 4-device host mesh
#   scripts/verify.sh --serve    # serving tier + incremental delta-refits
#                                # (registry round-trip, hot-swap, drift,
#                                # delta-refit bitwise parity)
#   scripts/verify.sh --analyze  # static analysis gate: repro.analysis
#                                # (lint + kernel contracts + protocol model)
#                                # plus ruff/mypy when installed
#
# Extra args after the mode flag are forwarded to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

mode=full
if [[ "${1:-}" == "--quick" ]]; then
  mode=quick
  shift
elif [[ "${1:-}" == "--cluster" ]]; then
  mode=cluster
  shift
elif [[ "${1:-}" == "--topology" ]]; then
  mode=topology
  shift
elif [[ "${1:-}" == "--serve" ]]; then
  mode=serve
  shift
elif [[ "${1:-}" == "--analyze" ]]; then
  mode=analyze
  shift
fi

# interpret-mode kernel parity: every Pallas kernel against its jnp
# oracle, the engine-parity sweep of the data-pass drivers, the
# column-bucketed fused-kernel parity/regression suite, the seeded-Ω
# tile-PRNG bitwise-parity suite, and the staged (P-reuse) schedule
# parity grid + crossover rule
parity() {
  python -m pytest -q tests/test_kernels.py tests/test_engine_parity.py \
    tests/test_bucketed_kernels.py tests/test_bucketed_properties.py \
    tests/test_seeded_omega.py tests/test_staged_schedule.py "$@"
}

# multi-worker map/combine/reduce: coordinator merge parity (bitwise vs
# single-process), kill/re-dispatch fault tolerance, and the store layer
# it is built on (URI schemes incl. the mem:// fake, row_shard seek +
# group striping, prefetch auto-tune, cursor resume)
cluster() {
  python -m pytest -q tests/test_cluster.py tests/test_cluster_failures.py \
    tests/test_store.py tests/test_store_resume.py "$@"
}

# execution-topology parity: Local ≡ Sharded ≡ Cluster ≡ Hybrid bitwise
# (both engines), hybrid worker kill/resume, heartbeat re-dispatch, and
# the collective-fused sharded-kernel path (|model| > 1 meshes) — with
# the in-process Sharded rows on a REAL 4-device host mesh (the flag
# must be set before jax initializes, hence here)
topology() {
  XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=4" \
    python -m pytest -q tests/test_exec_topologies.py \
    tests/test_cluster_failures.py tests/test_collective_fused.py "$@"
}

# serving tier + incremental refits: model-registry round-trip +
# corruption detection, zero-drop hot-swap under concurrent requests,
# drift signal → refit → recovery, and delta-refit bitwise parity
# (cold fit ≡ stateful fit + delta) across engines and topologies
serve() {
  python -m pytest -q tests/test_serve.py "$@"
}

# static analysis gate: the repro.analysis suite is mandatory (stdlib +
# jax only); ruff and mypy run when importable and are skipped with a
# notice otherwise (the runtime image does not ship them — CI installs
# both from requirements-dev.txt, so the gate is strict there)
analyze() {
  python -m repro.analysis
  if command -v ruff >/dev/null; then
    ruff check src/repro tests
  else
    echo "analyze: ruff not installed — skipping (CI runs it)"
  fi
  if command -v mypy >/dev/null; then
    mypy --config-file pyproject.toml src/repro/exec src/repro/store
  else
    echo "analyze: mypy not installed — skipping (CI runs it)"
  fi
}

case "$mode" in
  quick)    parity "$@" ;;
  cluster)  cluster "$@" ;;
  topology) topology "$@" ;;
  serve)    serve "$@" ;;
  analyze)  analyze ;;
  *)
    # the full pytest run already covers the cluster suite; parity is
    # re-run standalone to keep the kernel gate loud and isolated
    python -m pytest -x -q "$@"
    parity
    ;;
esac
